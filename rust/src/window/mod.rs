//! The **sliding window** (paper §2.3, §3.1): selective, level-of-detail
//! bounded visualisation access — online against the running simulation,
//! offline against any snapshot in the h5lite file.
//!
//! The key property in both modes: the data volume returned is bounded by
//! the grid *budget*, not by the domain size. Large windows come back at a
//! coarse level of detail (the interior d-grids hold the bottom-up averaged
//! values), small windows descend to the finest grids — "zooming into the
//! data" — so even a trillion-cell domain is explorable over a fixed-rate
//! link.
//!
//! ## Online path (paper Fig 3)
//!
//! 1. the front-end client sends a request to the **collector**'s TCP
//!    socket;
//! 2. the collector forwards the query to the neighbourhood server, which
//!    selects the relevant d-grids at the right level of detail;
//! 3. + 4. the owning processes (here: the shared domain state) provide the
//!    selected grid data to the collector;
//! 5. the collector streams the response back to the client.
//!
//! ## Offline path (paper §3.2)
//!
//! The same traversal over the snapshot datasets: start at the root grid
//! (always row 0 of `grid_property`), follow `subgrid uid` links through a
//! UID→row map, prune by `bounding box`, stop when descending would burst
//! the budget, and read *only the selected rows* of `current_cell_data`.
//! Chunk-compressed snapshots (h5lite format v2) decompress transparently
//! inside [`H5File::read_rows`]: each chunk's recorded codec byte selects
//! its own decode pipeline — codec-v2 files mix raw, LZ and LZ+entropy
//! extents within one dataset (the adaptive per-chunk selector), and the
//! window never has to know. The file's LRU chunk cache keeps the
//! row-at-a-time traversal from re-inflating the same chunk per row, even
//! when a multi-grid query straddles chunk boundaries — with the entropy
//! stage in play the cache matters more, since re-inflating a chunk now
//! costs a range-coder pass on top of the LZ copy loop.
//!
//! ## Byte-budgeted queries over the LOD pyramid
//!
//! [`offline_window_budgeted`] takes a **byte** budget and serves the
//! region of interest from the finest [`crate::lod`] pyramid level whose
//! cover fits it — a whole-domain query over a huge snapshot comes back as
//! a handful of coarse grids instead of every leaf, and zooming in
//! automatically lands on finer levels. [`offline_window_progressive`]
//! streams the same answer coarse-to-fine for immediate first paint.
//! Pyramid-less files (pre-LOD, or written with
//! `SnapshotOptions { lod: false, .. }`) fall back to the classic
//! traversal transparently. The online [`Collector`] speaks a second,
//! byte-budgeted request ([`query_budgeted`]) answered from the live
//! tree's restricted interior grids — the online twin of the pyramid.

use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::Simulation;
use crate::h5lite::{codec, H5File};
use crate::iokernel::{self, ROW_BYTES, ROW_ELEMS};
use crate::lod::{self, LodIndex};
use crate::tree::uid::{LocCode, Uid};
use crate::tree::BBox;
use crate::{DGRID_CELLS, NVAR};

/// One grid's worth of visualisation data.
#[derive(Clone, Debug)]
pub struct WindowGrid {
    pub uid: Uid,
    pub depth: u32,
    pub bbox: BBox,
    /// `NVAR · 16³` values: all variables' interiors, variable-major.
    pub data: Vec<f32>,
}

// ---------------------------------------------------------------------------
// offline window
// ---------------------------------------------------------------------------

/// Offline sliding-window query against the snapshot at time `t`.
pub fn offline_window(
    file: &H5File,
    t: f64,
    window: &BBox,
    budget: usize,
) -> Result<Vec<WindowGrid>> {
    let group = iokernel::ts_group(t);
    let ds_prop = file.dataset(&group, "grid_property")?;
    let ds_sub = file.dataset(&group, "subgrid_uid")?;
    let ds_bbox = file.dataset(&group, "bounding_box")?;
    let ds_cur = file.dataset(&group, "current_cell_data")?;
    let uids = file.read_all_u64(&ds_prop)?;
    if uids.is_empty() {
        bail!("window: empty snapshot");
    }
    // UID → row index (the offline analogue of the neighbourhood server)
    let row_of: std::collections::HashMap<u64, u64> = uids
        .iter()
        .enumerate()
        .map(|(r, &u)| (u, r as u64))
        .collect();

    let bbox_of = |row: u64| -> Result<BBox> {
        let b = codec::bytes_to_f64s(&file.read_rows(&ds_bbox, row, 1)?);
        Ok(BBox {
            min: [b[0], b[1], b[2]],
            max: [b[3], b[4], b[5]],
        })
    };
    let children_of = |row: u64| -> Result<Vec<u64>> {
        let subs = codec::bytes_to_u64s(&file.read_rows(&ds_sub, row, 1)?);
        Ok(subs
            .into_iter()
            .filter(|&u| u != 0)
            .filter_map(|u| row_of.get(&u).copied())
            .collect())
    };

    // LOD descent from the root (row 0), identical to
    // NeighbourhoodServer::select_window but over file rows.
    let mut current: Vec<u64> = if bbox_of(0)?.intersects(window) {
        vec![0]
    } else {
        Vec::new()
    };
    loop {
        let mut next = Vec::with_capacity(current.len() * 4);
        let mut descended = false;
        for &row in &current {
            let kids = children_of(row)?;
            if kids.is_empty() {
                next.push(row);
            } else {
                let hits: Vec<u64> = kids
                    .into_iter()
                    .filter(|&k| bbox_of(k).map(|b| b.intersects(window)).unwrap_or(false))
                    .collect();
                if hits.is_empty() {
                    next.push(row);
                } else {
                    descended = true;
                    next.extend(hits);
                }
            }
        }
        if !descended || next.len() > budget {
            break;
        }
        current = next;
    }

    // read only the selected rows
    current
        .into_iter()
        .map(|row| {
            let data = codec::bytes_to_f32s(&file.read_rows(&ds_cur, row, 1)?);
            let uid = Uid(uids[row as usize]);
            Ok(WindowGrid {
                uid,
                depth: uid.loc().depth(),
                bbox: bbox_of(row)?,
                data,
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// byte-budgeted offline window over the LOD pyramid
// ---------------------------------------------------------------------------

/// Answer of a byte-budgeted window query.
#[derive(Debug)]
pub struct LodWindow {
    pub grids: Vec<WindowGrid>,
    /// Pyramid level served: 0 = full resolution (the tree's leaves),
    /// `max` = the single root grid. Adaptive trees may mix in coarser
    /// ancestors where nothing finer is stored — each grid carries its own
    /// depth/bbox.
    pub level: u32,
    /// Cell-data payload bytes fetched to answer (the budget's currency;
    /// the topology/location indexes add a few KiB on top).
    pub bytes_read: u64,
    /// True when the answer came from stored pyramid levels; false on the
    /// full-resolution or fallback paths.
    pub from_pyramid: bool,
}

/// Sliding-window query under a **byte budget**: serve `window` from the
/// finest resolution whose cover fits `budget_bytes`, using the snapshot's
/// LOD pyramid when it has one. Level 0 (full resolution) reads the tree's
/// leaf grids; coarser levels read the pyramid datasets — a whole-domain
/// overview costs one grid row, not the whole snapshot. The answer always
/// holds at least one grid, even under a sub-grid budget. A pyramid-less
/// snapshot falls back to the classic grid-count traversal with the budget
/// converted to grids.
pub fn offline_window_budgeted(
    file: &H5File,
    t: f64,
    window: &BBox,
    budget_bytes: u64,
) -> Result<LodWindow> {
    let row_bytes = ROW_BYTES;
    let group = iokernel::ts_group(t);
    let Some(idx) = LodIndex::open(file, &group)? else {
        let budget_grids = (budget_bytes / row_bytes).max(1) as usize;
        let grids = offline_window(file, t, window, budget_grids)?;
        return Ok(LodWindow {
            bytes_read: grids.len() as u64 * row_bytes,
            grids,
            level: 0,
            from_pyramid: false,
        });
    };
    let domain = iokernel::read_domain(file)?;
    let d_max = idx.max_level();
    // finest level whose whole-cover byte count fits the budget (the
    // count is an O(1) upper bound, so the chosen level never bursts it);
    // the root level is the floor — an answer is always affordable
    let mut chosen = d_max;
    for l in 0..=d_max {
        if lod::intersect_count(&domain, d_max - l, window) * row_bytes <= budget_bytes {
            chosen = l;
            break;
        }
    }
    if chosen == 0 {
        let grids = offline_window(file, t, window, usize::MAX)?;
        return Ok(LodWindow {
            bytes_read: grids.len() as u64 * row_bytes,
            grids,
            level: 0,
            from_pyramid: false,
        });
    }
    read_pyramid_level(file, &idx, &domain, chosen, window, row_bytes)
}

/// Read the cover of `window` at pyramid level `l ≥ 1`. Coordinates an
/// adaptive tree never stored resolve to their nearest stored ancestor
/// (deduplicated), so the cover is complete at mixed depth.
fn read_pyramid_level(
    file: &H5File,
    idx: &LodIndex,
    domain: &BBox,
    l: u32,
    window: &BBox,
    row_bytes: u64,
) -> Result<LodWindow> {
    let d_max = idx.max_level();
    let depth = idx.level(l).ok_or_else(|| anyhow!("window: no lod level {l}"))?.depth;
    let [ri, rj, rk] = lod::coord_range(domain, depth, window);
    let mut picked: BTreeSet<(u32, u64)> = BTreeSet::new();
    for i in ri.0..ri.1 {
        for j in rj.0..rj.1 {
            for k in rk.0..rk.1 {
                let (mut lc, mut c) = (l, (i, j, k));
                loop {
                    let lvl = idx.level(lc).unwrap();
                    let row = LocCode::from_coords(lvl.depth, c.0, c.1, c.2)
                        .and_then(|loc| lvl.row_of(loc));
                    if let Some(row) = row {
                        picked.insert((lc, row));
                        break;
                    }
                    if lc >= d_max {
                        bail!("window: lod pyramid misses an ancestor for ({i},{j},{k})");
                    }
                    lc += 1;
                    c = (c.0 / 2, c.1 / 2, c.2 / 2);
                }
            }
        }
    }
    let mut grids = Vec::with_capacity(picked.len());
    let mut bytes_read = 0u64;
    for &(lc, row) in &picked {
        let lvl = idx.level(lc).unwrap();
        let data = lvl.read_row(file, row)?;
        bytes_read += row_bytes;
        let loc = lvl.locs[row as usize];
        let (i, j, k) = loc.coords();
        grids.push(WindowGrid {
            uid: Uid::new(0, 0, loc),
            depth: loc.depth(),
            bbox: lod::grid_bbox(domain, loc.depth(), i, j, k),
            data,
        });
    }
    Ok(LodWindow {
        grids,
        level: l,
        bytes_read,
        from_pyramid: true,
    })
}

/// Progressive refinement: stream `window` coarse-to-fine — the root level
/// first (immediate first paint), then each finer level while the
/// *cumulative* bytes stay within `total_budget_bytes`. The last element
/// is the finest affordable answer; the first is always emitted so the
/// viewer never starves. Falls back to a single budgeted answer on
/// pyramid-less snapshots.
pub fn offline_window_progressive(
    file: &H5File,
    t: f64,
    window: &BBox,
    total_budget_bytes: u64,
) -> Result<Vec<LodWindow>> {
    let row_bytes = ROW_BYTES;
    let group = iokernel::ts_group(t);
    let Some(idx) = LodIndex::open(file, &group)? else {
        return Ok(vec![offline_window_budgeted(file, t, window, total_budget_bytes)?]);
    };
    let domain = iokernel::read_domain(file)?;
    let d_max = idx.max_level();
    let mut out: Vec<LodWindow> = Vec::new();
    let mut spent = 0u64;
    for l in (0..=d_max).rev() {
        let cost = lod::intersect_count(&domain, d_max - l, window) * row_bytes;
        if !out.is_empty() && spent + cost > total_budget_bytes {
            break;
        }
        let step = if l == 0 {
            let grids = offline_window(file, t, window, usize::MAX)?;
            LodWindow {
                bytes_read: grids.len() as u64 * row_bytes,
                grids,
                level: 0,
                from_pyramid: false,
            }
        } else {
            read_pyramid_level(file, &idx, &domain, l, window, row_bytes)?
        };
        spent += step.bytes_read;
        out.push(step);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// online window: collector process + client
// ---------------------------------------------------------------------------

const REQ_MAGIC: u32 = 0x5357_494E; // "SWIN"
/// Budget-aware request: bbox + byte budget, answered at the finest
/// level-of-detail whose cover fits (the online twin of the pyramid —
/// interior d-grids hold the restricted averages the bottom-up step
/// maintains).
const LOD_REQ_MAGIC: u32 = 0x5357_4C44; // "SWLD"
/// Wire length of one grid record: uid, depth, bbox, cell data.
const REC_LEN: usize = 8 + 4 + 48 + ROW_ELEMS * 4;

/// Handle to a running collector thread.
pub struct Collector {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Collector {
    /// Spawn the collector on an ephemeral localhost port, serving
    /// sliding-window queries against the shared simulation state.
    pub fn spawn(sim: Arc<RwLock<Simulation>>) -> Result<Collector> {
        let listener = TcpListener::bind("127.0.0.1:0").context("collector bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = handle_client(stream, &sim);
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Collector {
            addr,
            stop,
            handle: Some(handle),
        })
    }
}

impl Drop for Collector {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_client(mut stream: TcpStream, sim: &Arc<RwLock<Simulation>>) -> Result<()> {
    stream.set_nodelay(true).ok();
    // ---- request: magic, bbox, budget --------------------------------- (1)
    let mut magic = [0u8; 4];
    stream.read_exact(&mut magic)?;
    let mut bbox_buf = [0u8; 48];
    let out = match u32::from_le_bytes(magic) {
        REQ_MAGIC => {
            stream.read_exact(&mut bbox_buf)?;
            let window = decode_bbox(&bbox_buf);
            let mut b = [0u8; 4];
            stream.read_exact(&mut b)?;
            respond(sim, &window, u32::from_le_bytes(b) as usize, false)?
        }
        LOD_REQ_MAGIC => {
            stream.read_exact(&mut bbox_buf)?;
            let window = decode_bbox(&bbox_buf);
            let mut b = [0u8; 8];
            stream.read_exact(&mut b)?;
            // byte budget → grid budget: the server-side level selection
            // then picks the finest depth whose cover fits it
            let budget = (u64::from_le_bytes(b) / REC_LEN as u64).max(1) as usize;
            respond(sim, &window, budget, true)?
        }
        _ => bail!("collector: bad request magic"),
    };
    stream.write_all(&out)?;
    Ok(())
}

fn decode_bbox(buf: &[u8; 48]) -> BBox {
    let f = |i: usize| f64::from_le_bytes(buf[i * 8..(i + 1) * 8].try_into().unwrap());
    BBox {
        min: [f(0), f(1), f(2)],
        max: [f(3), f(4), f(5)],
    }
}

/// Steps (2)–(5) of the Fig 3 query path: the neighbourhood server selects
/// the grids at the budget's level of detail, the owning processes provide
/// the data, the collector serialises the response. `lod_header` prefixes
/// the record stream with the finest tree depth served (the budgeted
/// protocol's level report).
fn respond(
    sim: &Arc<RwLock<Simulation>>,
    window: &BBox,
    budget: usize,
    lod_header: bool,
) -> Result<Vec<u8>> {
    let sim = sim.read().map_err(|_| anyhow!("collector: lock poisoned"))?;
    let sel = sim.nbs.select_window(window, budget);
    let mut out: Vec<u8> = Vec::with_capacity(8 + sel.len() * REC_LEN);
    if lod_header {
        let depth = sel
            .iter()
            .map(|&i| sim.nbs.tree.node(i).depth())
            .max()
            .unwrap_or(0);
        out.extend_from_slice(&depth.to_le_bytes());
    }
    out.extend_from_slice(&(sel.len() as u32).to_le_bytes());
    let mut interior = vec![0.0f32; DGRID_CELLS];
    for idx in sel {
        let node = sim.nbs.tree.node(idx);
        out.extend_from_slice(&node.uid().0.to_le_bytes());
        out.extend_from_slice(&node.depth().to_le_bytes());
        for v in node.bbox.min.iter().chain(node.bbox.max.iter()) {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in 0..NVAR {
            sim.grids[idx as usize]
                .cur
                .extract_interior(v, &mut interior);
            for x in &interior {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    Ok(out)
}

/// Read `n`-prefixed grid records off the wire (client side).
fn read_grid_records(stream: &mut TcpStream) -> Result<Vec<WindowGrid>> {
    let mut n_buf = [0u8; 4];
    stream.read_exact(&mut n_buf)?;
    let n = u32::from_le_bytes(n_buf) as usize;
    let mut grids = Vec::with_capacity(n);
    let mut rec = vec![0u8; REC_LEN];
    for _ in 0..n {
        stream.read_exact(&mut rec)?;
        let uid = Uid(u64::from_le_bytes(rec[0..8].try_into().unwrap()));
        let depth = u32::from_le_bytes(rec[8..12].try_into().unwrap());
        let f = |i: usize| f64::from_le_bytes(rec[12 + i * 8..20 + i * 8].try_into().unwrap());
        let bbox = BBox {
            min: [f(0), f(1), f(2)],
            max: [f(3), f(4), f(5)],
        };
        let data = codec::bytes_to_f32s(&rec[60..]);
        grids.push(WindowGrid {
            uid,
            depth,
            bbox,
            data,
        });
    }
    Ok(grids)
}

/// Front-end client: one sliding-window query over TCP.
pub fn query(addr: SocketAddr, window: &BBox, budget: u32) -> Result<Vec<WindowGrid>> {
    let mut stream = TcpStream::connect(addr).context("window client connect")?;
    let mut req = Vec::with_capacity(56);
    req.extend_from_slice(&REQ_MAGIC.to_le_bytes());
    for v in window.min.iter().chain(window.max.iter()) {
        req.extend_from_slice(&v.to_le_bytes());
    }
    req.extend_from_slice(&budget.to_le_bytes());
    stream.write_all(&req)?;
    read_grid_records(&mut stream)
}

/// Answer of a byte-budgeted online query.
#[derive(Debug)]
pub struct OnlineLodWindow {
    pub grids: Vec<WindowGrid>,
    /// Finest tree depth the collector served.
    pub depth: u32,
    /// Payload bytes received (≤ the requested budget, modulo the
    /// one-grid floor).
    pub bytes: u64,
}

/// Front-end client: one **byte-budgeted** sliding-window query — the
/// collector picks the finest level of detail whose cover fits
/// `budget_bytes` and reports the depth it served.
pub fn query_budgeted(
    addr: SocketAddr,
    window: &BBox,
    budget_bytes: u64,
) -> Result<OnlineLodWindow> {
    let mut stream = TcpStream::connect(addr).context("window client connect")?;
    let mut req = Vec::with_capacity(60);
    req.extend_from_slice(&LOD_REQ_MAGIC.to_le_bytes());
    for v in window.min.iter().chain(window.max.iter()) {
        req.extend_from_slice(&v.to_le_bytes());
    }
    req.extend_from_slice(&budget_bytes.to_le_bytes());
    stream.write_all(&req)?;
    let mut d = [0u8; 4];
    stream.read_exact(&mut d)?;
    let depth = u32::from_le_bytes(d);
    let grids = read_grid_records(&mut stream)?;
    let bytes = (grids.len() * REC_LEN) as u64;
    Ok(OnlineLodWindow {
        grids,
        depth,
        bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{IoTuning, Machine};
    use crate::pario::ParallelIo;
    use crate::physics::bc::DomainBc;
    use crate::physics::Params;
    use crate::tree::SpaceTree;
    use crate::var;

    fn sim(depth: u32) -> Simulation {
        let tree = SpaceTree::full(BBox::unit(), depth);
        let mut s = Simulation::new(
            tree,
            3,
            DomainBc::all_walls(),
            Params::isothermal(0.01, 1.0 / 32.0, 0.01),
        );
        // paint P with the arena index so grids are distinguishable
        for (i, g) in s.grids.iter_mut().enumerate() {
            let f = vec![i as f32; DGRID_CELLS];
            g.cur.set_interior(var::P, &f);
        }
        s
    }

    #[test]
    fn offline_window_full_domain_coarse() {
        let p = std::env::temp_dir().join(format!("win_off_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.5).unwrap();
        // budget 1 → root only (coarsest LOD)
        let w = offline_window(&f, 0.5, &BBox::unit(), 1).unwrap();
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].depth, 0);
        assert_eq!(w[0].data.len(), ROW_ELEMS);
        // budget 8 → depth 1
        let w = offline_window(&f, 0.5, &BBox::unit(), 8).unwrap();
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|g| g.depth == 1));
        // large budget → all 64 leaves
        let w = offline_window(&f, 0.5, &BBox::unit(), 1000).unwrap();
        assert_eq!(w.len(), 64);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn offline_window_zoom_returns_correct_data() {
        let p = std::env::temp_dir().join(format!("win_zoom_{}.h5", std::process::id()));
        let s = sim(1);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0).unwrap();
        let corner = BBox {
            min: [0.0; 3],
            max: [0.2; 3],
        };
        let w = offline_window(&f, 0.0, &corner, 64).unwrap();
        assert_eq!(w.len(), 1, "one leaf covers the corner window");
        // its pressure payload equals the painted arena index
        let idx = s
            .nbs
            .tree
            .nodes
            .iter()
            .position(|n| n.is_leaf() && n.bbox.contains_point([0.01; 3]))
            .unwrap();
        let pslice = &w[0].data[var::P * DGRID_CELLS..(var::P + 1) * DGRID_CELLS];
        assert!(pslice.iter().all(|&x| x == idx as f32));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn offline_window_identical_on_compressed_and_raw_snapshots() {
        let p = std::env::temp_dir().join(format!("win_comp_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        let comp = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            0.0,
            &iokernel::SnapshotOptions::default(),
        )
        .unwrap();
        iokernel::write_snapshot_with(
            &mut f,
            &io,
            &s.nbs.tree,
            &s.part,
            &s.grids,
            1.0,
            &iokernel::SnapshotOptions::uncompressed(),
        )
        .unwrap();
        assert!(comp.io.stored_bytes < comp.io.bytes);
        // every zoom level returns identical grids + payloads on both
        for budget in [1usize, 8, 1000] {
            let a = offline_window(&f, 0.0, &BBox::unit(), budget).unwrap();
            let b = offline_window(&f, 1.0, &BBox::unit(), budget).unwrap();
            assert_eq!(a.len(), b.len(), "budget {budget}");
            for (ga, gb) in a.iter().zip(&b) {
                assert_eq!(ga.uid.0, gb.uid.0);
                assert_eq!(ga.data, gb.data);
            }
        }
        std::fs::remove_file(&p).ok();
    }

    /// Cell-data bytes of one grid row.
    const RB: u64 = ROW_BYTES;

    fn snapshot_file(name: &str, s: &Simulation, t: f64) -> H5File {
        let p = std::env::temp_dir().join(format!("win_{name}_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, t).unwrap();
        f
    }

    #[test]
    fn budgeted_window_serves_pyramid_levels() {
        let s = sim(2);
        let f = snapshot_file("lod_levels", &s, 0.5);
        // generous budget → full resolution, same grids as the classic path
        let full = offline_window_budgeted(&f, 0.5, &BBox::unit(), u64::MAX).unwrap();
        assert_eq!(full.level, 0);
        assert_eq!(full.grids.len(), 64);
        assert_eq!(full.bytes_read, 64 * RB);
        // an 8-grid budget → pyramid level 1 (the 8 depth-1 folds)
        let mid = offline_window_budgeted(&f, 0.5, &BBox::unit(), 8 * RB).unwrap();
        assert_eq!(mid.level, 1);
        assert!(mid.from_pyramid);
        assert_eq!(mid.grids.len(), 8);
        assert!(mid.grids.iter().all(|g| g.depth == 1));
        assert_eq!(mid.bytes_read, 8 * RB);
        // the served values are exact folds of the painted leaves: octant 0
        // of a level-1 grid holds its first child's (constant) pressure
        let g1 = &mid.grids[0];
        let child = s.nbs.tree.lookup(g1.uid.loc().child(0)).unwrap();
        assert_eq!(g1.data[var::P * DGRID_CELLS], child as f32);
        // a one-grid budget → the root overview, 1/64 of the full bytes
        let root = offline_window_budgeted(&f, 0.5, &BBox::unit(), RB).unwrap();
        assert_eq!(root.level, 2);
        assert_eq!(root.grids.len(), 1);
        assert_eq!(root.grids[0].depth, 0);
        assert_eq!(root.bytes_read, RB);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn budgeted_zoom_descends_levels_at_fixed_budget() {
        let s = sim(2);
        let f = snapshot_file("lod_zoom", &s, 0.0);
        let budget = 4 * RB;
        let whole = offline_window_budgeted(&f, 0.0, &BBox::unit(), budget).unwrap();
        let octant = offline_window_budgeted(
            &f,
            0.0,
            &BBox {
                min: [0.0; 3],
                max: [0.5; 3],
            },
            budget,
        )
        .unwrap();
        let corner = offline_window_budgeted(
            &f,
            0.0,
            &BBox {
                min: [0.0; 3],
                max: [0.25; 3],
            },
            budget,
        )
        .unwrap();
        // shrinking the window at a fixed byte budget lands on finer levels
        assert_eq!(whole.level, 2);
        assert_eq!(octant.level, 1);
        assert_eq!(corner.level, 0);
        for w in [&whole, &octant, &corner] {
            assert!(w.bytes_read <= budget, "{} > {budget}", w.bytes_read);
            assert!(!w.grids.is_empty());
        }
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn progressive_refinement_streams_coarse_to_fine() {
        let s = sim(2);
        let f = snapshot_file("lod_prog", &s, 0.0);
        // budget for the whole cascade: 1 + 8 + 64 grids
        let steps =
            offline_window_progressive(&f, 0.0, &BBox::unit(), 73 * RB).unwrap();
        assert_eq!(steps.len(), 3);
        assert_eq!(
            steps.iter().map(|s| s.level).collect::<Vec<_>>(),
            vec![2, 1, 0]
        );
        assert_eq!(steps[0].grids.len(), 1);
        assert_eq!(steps[2].grids.len(), 64);
        let total: u64 = steps.iter().map(|s| s.bytes_read).sum();
        assert!(total <= 73 * RB);
        // a sub-grid budget still paints the coarsest answer
        let tiny = offline_window_progressive(&f, 0.0, &BBox::unit(), 1).unwrap();
        assert_eq!(tiny.len(), 1);
        assert_eq!(tiny[0].level, 2);
        std::fs::remove_file(&f.path).ok();
    }

    #[test]
    fn pyramid_less_snapshot_falls_back_unchanged() {
        let s = sim(2);
        let p = std::env::temp_dir().join(format!("win_nolod_{}.h5", std::process::id()));
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        let opts = iokernel::SnapshotOptions {
            lod: false,
            ..iokernel::SnapshotOptions::default()
        };
        iokernel::write_snapshot_with(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 0.0, &opts)
            .unwrap();
        // the classic API answers exactly as before the pyramid existed
        let classic = offline_window(&f, 0.0, &BBox::unit(), 8).unwrap();
        assert_eq!(classic.len(), 8);
        // and the budgeted API degrades to the grid-count traversal
        let w = offline_window_budgeted(&f, 0.0, &BBox::unit(), 8 * RB).unwrap();
        assert!(!w.from_pyramid);
        assert_eq!(w.level, 0);
        assert_eq!(w.grids.len(), 8);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn online_budgeted_query_selects_depth() {
        let s = sim(2);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let rec = REC_LEN as u64;
        let coarse = query_budgeted(collector.addr, &BBox::unit(), rec).unwrap();
        assert_eq!(coarse.grids.len(), 1);
        assert_eq!(coarse.depth, 0);
        assert!(coarse.bytes <= rec);
        let mid = query_budgeted(collector.addr, &BBox::unit(), 8 * rec).unwrap();
        assert_eq!(mid.grids.len(), 8);
        assert_eq!(mid.depth, 1);
        assert!(mid.bytes <= 8 * rec);
        // zooming at the same budget reaches the leaves
        let corner = BBox {
            min: [0.0; 3],
            max: [0.2; 3],
        };
        let zoom = query_budgeted(collector.addr, &corner, 8 * rec).unwrap();
        assert_eq!(zoom.depth, 2);
        // the legacy fixed-count protocol still works on the same socket
        let legacy = query(collector.addr, &BBox::unit(), 8).unwrap();
        assert_eq!(legacy.len(), 8);
    }

    #[test]
    fn online_collector_roundtrip() {
        let s = sim(2);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        // full-domain query at budget 8 → the 8 depth-1 grids
        let grids = query(collector.addr, &BBox::unit(), 8).unwrap();
        assert_eq!(grids.len(), 8);
        assert!(grids.iter().all(|g| g.depth == 1));
        assert!(grids.iter().all(|g| g.data.len() == ROW_ELEMS));
        // zoomed query descends deeper
        let corner = BBox {
            min: [0.0; 3],
            max: [0.1; 3],
        };
        let zoom = query(collector.addr, &corner, 8).unwrap();
        assert!(zoom.iter().any(|g| g.depth == 2), "{zoom:?} depths");
    }

    #[test]
    fn online_window_sees_live_updates() {
        let s = sim(1);
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let before = query(collector.addr, &BBox::unit(), 1).unwrap();
        // mutate the root grid's pressure
        {
            let mut sim = shared.write().unwrap();
            let f = vec![777.0f32; DGRID_CELLS];
            sim.grids[0].cur.set_interior(var::P, &f);
        }
        let after = query(collector.addr, &BBox::unit(), 1).unwrap();
        let pr = |w: &[WindowGrid]| w[0].data[var::P * DGRID_CELLS];
        assert_ne!(pr(&before), pr(&after));
        assert_eq!(pr(&after), 777.0);
    }

    #[test]
    fn online_and_offline_agree() {
        let p = std::env::temp_dir().join(format!("win_agree_{}.h5", std::process::id()));
        let s = sim(2);
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 3);
        let mut f = H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &s.params, &s.nbs.tree, 3).unwrap();
        iokernel::write_snapshot(&mut f, &io, &s.nbs.tree, &s.part, &s.grids, 1.5).unwrap();
        let shared = Arc::new(RwLock::new(s));
        let collector = Collector::spawn(shared.clone()).unwrap();
        let win = BBox {
            min: [0.4, 0.4, 0.4],
            max: [0.6, 0.6, 0.6],
        };
        let online = query(collector.addr, &win, 16).unwrap();
        let offline = offline_window(&f, 1.5, &win, 16).unwrap();
        assert_eq!(online.len(), offline.len());
        let key = |g: &WindowGrid| g.uid.loc().0;
        let mut on: Vec<_> = online.iter().map(key).collect();
        let mut off: Vec<_> = offline.iter().map(key).collect();
        on.sort_unstable();
        off.sort_unstable();
        assert_eq!(on, off);
        std::fs::remove_file(&p).ok();
    }
}
