//! The **simulation coordinator**: owns the domain state (tree + d-grids +
//! partition), drives the Chorin projection time loop through the compute
//! backend, triggers checkpoints through the I/O kernel, and applies
//! steering commands — the Rust L3 event loop of the three-layer stack.

use anyhow::Result;

use crate::exchange::{self, ExchangeStats, Gen};
use crate::iokernel::{self, SnapshotReport};
use crate::nbs::NeighbourhoodServer;
use crate::pario::ParallelIo;
use crate::physics::bc::{apply_solid_mask, DomainBc};
use crate::physics::{ComputeBackend, Params};
use crate::solver::{self, batch, SolveStats, SolverConfig};
use crate::tree::dgrid::DGrid;
use crate::tree::sfc::{self, Partition};
use crate::tree::SpaceTree;
use crate::{var, DGRID_CELLS};

/// Report of one time step.
#[derive(Clone, Copy, Debug)]
pub struct StepReport {
    pub step: u64,
    pub t: f64,
    pub exchange: ExchangeStats,
    pub solve: SolveStats,
    /// RMS of the PPE right-hand side before the solve (∝ ‖∇·u*‖).
    pub div_rms: f32,
    pub seconds: f64,
}

/// The live simulation state.
pub struct Simulation {
    pub nbs: NeighbourhoodServer,
    pub part: Partition,
    pub grids: Vec<DGrid>,
    pub bc: DomainBc,
    pub params: Params,
    pub solver_cfg: SolverConfig,
    pub t: f64,
    pub step: u64,
    /// True when any grid carries solid cells (enables mask pass).
    pub has_solids: bool,
}

impl Simulation {
    /// Build a fresh simulation over `tree`, partitioned onto `n_ranks`.
    pub fn new(mut tree: SpaceTree, n_ranks: u32, bc: DomainBc, params: Params) -> Simulation {
        let part = sfc::partition(&mut tree, n_ranks);
        let grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        Simulation {
            nbs: NeighbourhoodServer::new(tree),
            part,
            grids,
            bc,
            params,
            solver_cfg: SolverConfig::per_step(),
            t: 0.0,
            step: 0,
            has_solids: false,
        }
    }

    /// Resume from a restored checkpoint (paper §3.2: topology comes from
    /// the file, not from the neighbourhood server's serial decomposition).
    pub fn from_snapshot(snap: iokernel::RestoredSnapshot, bc: DomainBc) -> Simulation {
        let has_solids = snap.grids.iter().any(|g| {
            g.cell_type
                .iter()
                .any(|&c| crate::tree::dgrid::CellType::from_u8(c).is_solid())
        });
        Simulation {
            nbs: NeighbourhoodServer::new(snap.tree),
            part: snap.part,
            grids: snap.grids,
            bc,
            params: snap.params,
            solver_cfg: SolverConfig::per_step(),
            t: snap.t,
            step: 0,
            has_solids,
        }
    }

    /// Uniform initial condition: velocity zero, temperature `t0`.
    pub fn init_temperature(&mut self, t0: f32) {
        for g in &mut self.grids {
            for gen in [Gen::Cur, Gen::Prev] {
                let fs = gen.of_mut(g);
                for x in fs.var_mut(var::T).iter_mut() {
                    *x = t0;
                }
            }
        }
    }

    /// Leaf indices grouped by depth (ascending) — compute happens on
    /// leaves, coarser d-grids carry restricted copies.
    pub fn leaves_by_depth(&self) -> Vec<(u32, Vec<u32>)> {
        let mut depths: Vec<u32> = self
            .nbs
            .tree
            .nodes
            .iter()
            .filter(|n| n.is_leaf())
            .map(|n| n.depth())
            .collect();
        depths.sort_unstable();
        depths.dedup();
        depths
            .into_iter()
            .map(|d| {
                (
                    d,
                    self.nbs
                        .tree
                        .nodes_at_depth(d)
                        .into_iter()
                        .filter(|&i| self.nbs.tree.node(i).is_leaf())
                        .collect(),
                )
            })
            .collect()
    }

    /// Total cells on leaf grids.
    pub fn n_cells(&self) -> u64 {
        self.nbs.tree.n_leaf_cells()
    }

    /// Advance one time step (Chorin projection, paper §2.1):
    /// predictor → divergence → multigrid pressure solve → correction.
    pub fn step(&mut self, backend: &dyn ComputeBackend) -> StepReport {
        let t0 = std::time::Instant::now();
        let leaves = self.leaves_by_depth();
        let mut stats = ExchangeStats::default();

        // 0. previous generation <- current (restart/time-derivative data)
        for g in &mut self.grids {
            g.prev.clone_from(&g.cur);
        }

        // 1. communication phase: bottom-up, horizontal, top-down on all
        //    variables of the current generation
        let vars = [var::U, var::V, var::W, var::P, var::T];
        stats.merge(&exchange::full_exchange(
            &self.nbs,
            &mut self.grids,
            Gen::Cur,
            &vars,
            &self.bc,
        ));

        // 2. predictor on every leaf level: u* → temp, T' → cur
        let mut bu = Vec::new();
        let mut bv = Vec::new();
        let mut bw = Vec::new();
        let mut bt = Vec::new();
        let mut ou = Vec::new();
        let mut ov = Vec::new();
        let mut ow = Vec::new();
        let mut ot = Vec::new();
        for (d, idxs) in &leaves {
            let par = self.par_at(*d);
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::U, &mut bu);
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::V, &mut bv);
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::W, &mut bw);
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::T, &mut bt);
            let n = idxs.len() * DGRID_CELLS;
            ou.resize(n, 0.0);
            ov.resize(n, 0.0);
            ow.resize(n, 0.0);
            ot.resize(n, 0.0);
            backend.predictor(
                idxs.len(),
                &bu,
                &bv,
                &bw,
                &bt,
                &par,
                &mut ou,
                &mut ov,
                &mut ow,
                &mut ot,
            );
            batch::scatter_interior(&mut self.grids, idxs, Gen::Temp, var::U, &ou);
            batch::scatter_interior(&mut self.grids, idxs, Gen::Temp, var::V, &ov);
            batch::scatter_interior(&mut self.grids, idxs, Gen::Temp, var::W, &ow);
            batch::scatter_interior(&mut self.grids, idxs, Gen::Cur, var::T, &ot);
        }

        // 3. exchange tentative velocity ghosts, then PPE rhs per level
        let mut div_sum = 0.0f64;
        let mut div_cells = 0u64;
        for (d, idxs) in &leaves {
            for v in [var::U, var::V, var::W] {
                solver::level_exchange(&self.nbs, &mut self.grids, *d, Gen::Temp, v, &self.bc);
            }
            let par = self.par_at(*d);
            batch::pack_halo(&self.grids, idxs, Gen::Temp, var::U, &mut bu);
            batch::pack_halo(&self.grids, idxs, Gen::Temp, var::V, &mut bv);
            batch::pack_halo(&self.grids, idxs, Gen::Temp, var::W, &mut bw);
            let n = idxs.len() * DGRID_CELLS;
            ou.resize(n, 0.0);
            backend.divergence(idxs.len(), &bu, &bv, &bw, &par, &mut ou);
            div_sum += ou.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
            div_cells += n as u64;
            batch::scatter_interior(&mut self.grids, idxs, Gen::Temp, var::P, &ou);
        }
        let div_rms = ((div_sum / div_cells.max(1) as f64) as f32).sqrt();

        // 3b. enforce solvability when the pressure has no Dirichlet
        //     anchor anywhere (all-Neumann BC): subtract the global mean.
        if self.pressure_is_singular() {
            self.subtract_rhs_mean(&leaves);
        }

        // 4. multigrid pressure solve (warm-started from the previous p)
        let solve = solver::solve_pressure(
            &self.nbs,
            &mut self.grids,
            &self.bc,
            &self.params,
            backend,
            &self.solver_cfg,
        );

        // 5. projection: corrected velocity back into cur
        for (d, idxs) in &leaves {
            solver::level_exchange(&self.nbs, &mut self.grids, *d, Gen::Cur, var::P, &self.bc);
            let par = self.par_at(*d);
            batch::pack_interior(&self.grids, idxs, Gen::Temp, var::U, &mut bu);
            batch::pack_interior(&self.grids, idxs, Gen::Temp, var::V, &mut bv);
            batch::pack_interior(&self.grids, idxs, Gen::Temp, var::W, &mut bw);
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::P, &mut bt);
            let n = idxs.len() * DGRID_CELLS;
            ou.resize(n, 0.0);
            ov.resize(n, 0.0);
            ow.resize(n, 0.0);
            backend.correct(
                idxs.len(),
                &bu,
                &bv,
                &bw,
                &bt,
                &par,
                &mut ou,
                &mut ov,
                &mut ow,
            );
            batch::scatter_interior(&mut self.grids, idxs, Gen::Cur, var::U, &ou);
            batch::scatter_interior(&mut self.grids, idxs, Gen::Cur, var::V, &ov);
            batch::scatter_interior(&mut self.grids, idxs, Gen::Cur, var::W, &ow);
        }

        // 6. solid-cell constraints (obstacle geometry)
        if self.has_solids {
            for g in &mut self.grids {
                apply_solid_mask(g);
            }
        }

        self.t += self.params.dt as f64;
        self.step += 1;
        StepReport {
            step: self.step,
            t: self.t,
            exchange: stats,
            solve,
            div_rms,
            seconds: t0.elapsed().as_secs_f64(),
        }
    }

    fn par_at(&self, depth: u32) -> Params {
        self.params.at_h(self.nbs.tree.h_at_depth(depth) as f32)
    }

    /// No Dirichlet pressure anywhere ⇒ the PPE is singular.
    fn pressure_is_singular(&self) -> bool {
        use crate::physics::bc::VarBc;
        self.bc
            .faces
            .iter()
            .all(|f| !matches!(f.per_var[var::P], VarBc::Dirichlet(_)))
    }

    fn subtract_rhs_mean(&mut self, leaves: &[(u32, Vec<u32>)]) {
        let mut sum = 0.0f64;
        let mut count = 0u64;
        let mut buf = vec![0.0f32; DGRID_CELLS];
        for (_, idxs) in leaves {
            for &i in idxs {
                self.grids[i as usize]
                    .temp
                    .extract_interior(var::P, &mut buf);
                sum += buf.iter().map(|&x| x as f64).sum::<f64>();
                count += buf.len() as u64;
            }
        }
        let mean = (sum / count.max(1) as f64) as f32;
        for (_, idxs) in leaves {
            for &i in idxs {
                self.grids[i as usize]
                    .temp
                    .extract_interior(var::P, &mut buf);
                for x in buf.iter_mut() {
                    *x -= mean;
                }
                self.grids[i as usize].temp.set_interior(var::P, &buf);
            }
        }
    }

    /// Write a checkpoint snapshot of the current state.
    pub fn write_checkpoint(
        &self,
        file: &mut crate::h5lite::H5File,
        io: &ParallelIo,
    ) -> Result<SnapshotReport> {
        iokernel::write_snapshot(file, io, &self.nbs.tree, &self.part, &self.grids, self.t)
    }

    /// RMS of the discrete divergence of the *current* velocity (quality
    /// metric for tests and the e2e driver).
    pub fn velocity_divergence_rms(&mut self, backend: &dyn ComputeBackend) -> f32 {
        let leaves = self.leaves_by_depth();
        let mut bu = Vec::new();
        let mut bv = Vec::new();
        let mut bw = Vec::new();
        let mut out = Vec::new();
        let mut sum = 0.0f64;
        let mut cells = 0u64;
        for (d, idxs) in &leaves {
            for v in [var::U, var::V, var::W] {
                solver::level_exchange(&self.nbs, &mut self.grids, *d, Gen::Cur, v, &self.bc);
            }
            let mut par = self.par_at(*d);
            par.dt = 1.0;
            par.rho = 1.0;
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::U, &mut bu);
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::V, &mut bv);
            batch::pack_halo(&self.grids, idxs, Gen::Cur, var::W, &mut bw);
            out.resize(idxs.len() * DGRID_CELLS, 0.0);
            backend.divergence(idxs.len(), &bu, &bv, &bw, &par, &mut out);
            sum += out.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
            cells += out.len() as u64;
        }
        ((sum / cells.max(1) as f64) as f32).sqrt()
    }

    /// Kinetic energy per cell over the leaves.
    pub fn kinetic_energy(&self) -> f64 {
        let mut sum = 0.0f64;
        let mut cells = 0u64;
        let mut buf = vec![0.0f32; DGRID_CELLS];
        for (i, n) in self.nbs.tree.nodes.iter().enumerate() {
            if !n.is_leaf() {
                continue;
            }
            for v in [var::U, var::V, var::W] {
                self.grids[i].cur.extract_interior(v, &mut buf);
                sum += buf.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
            }
            cells += DGRID_CELLS as u64;
        }
        sum / cells.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::physics::RustBackend;
    use crate::tree::BBox;

    fn params(n_cells_per_dim: f64) -> Params {
        Params {
            dt: 0.002,
            h: 0.0,
            nu: 0.01,
            alpha: 0.01,
            beta_g: 0.0,
            t_inf: 300.0,
            q_int: 0.0,
            rho: 1.0,
            omega: 1.0,
        }
        .at_h(1.0 / n_cells_per_dim as f32)
    }

    #[test]
    fn step_advances_time_and_counters() {
        let tree = SpaceTree::full(BBox::unit(), 1);
        let mut sim = Simulation::new(tree, 2, DomainBc::channel(0.5, 300.0), params(32.0));
        sim.init_temperature(300.0);
        let rep = sim.step(&RustBackend);
        assert_eq!(rep.step, 1);
        assert!((sim.t - 0.002).abs() < 1e-9);
        assert!(rep.seconds > 0.0);
        assert!(rep.exchange.total_bytes > 0);
    }

    #[test]
    fn channel_flow_develops_velocity() {
        let tree = SpaceTree::full(BBox::unit(), 1);
        let mut sim = Simulation::new(tree, 1, DomainBc::channel(1.0, 300.0), params(32.0));
        sim.init_temperature(300.0);
        for _ in 0..5 {
            sim.step(&RustBackend);
        }
        assert!(sim.kinetic_energy() > 1e-6, "{}", sim.kinetic_energy());
    }

    #[test]
    fn projection_keeps_divergence_bounded() {
        let tree = SpaceTree::full(BBox::unit(), 1);
        let mut sim = Simulation::new(tree, 2, DomainBc::channel(1.0, 300.0), params(32.0));
        sim.init_temperature(300.0);
        let mut last = 0.0;
        for _ in 0..5 {
            let rep = sim.step(&RustBackend);
            last = rep.solve.final_residual;
        }
        let div = sim.velocity_divergence_rms(&RustBackend);
        // the corrected field's divergence must be far below the inflow scale
        assert!(div < 0.5, "div={div} last_res={last}");
    }

    #[test]
    fn all_walls_cavity_is_singular_and_stable() {
        let tree = SpaceTree::full(BBox::unit(), 1);
        let mut sim = Simulation::new(tree, 1, DomainBc::all_walls(), params(32.0));
        sim.init_temperature(300.0);
        assert!(sim.pressure_is_singular());
        for _ in 0..3 {
            let rep = sim.step(&RustBackend);
            assert!(rep.div_rms.is_finite());
        }
        // no flow from nothing
        assert!(sim.kinetic_energy() < 1e-8);
    }

    #[test]
    fn buoyancy_drives_flow_in_heated_cavity() {
        let tree = SpaceTree::full(BBox::unit(), 1);
        let mut par = params(32.0);
        par.beta_g = 5.0;
        let mut sim = Simulation::new(tree, 1, DomainBc::all_walls(), par);
        sim.init_temperature(300.0);
        // heat the bottom of one grid
        use crate::tree::dgrid::pidx;
        for g in sim.grids.iter_mut().skip(1).take(1) {
            for i in 1..=8 {
                for j in 1..=8 {
                    g.cur.var_mut(var::T)[pidx(i, j, 1)] = 320.0;
                    g.prev.var_mut(var::T)[pidx(i, j, 1)] = 320.0;
                }
            }
        }
        for _ in 0..3 {
            sim.step(&RustBackend);
        }
        assert!(sim.kinetic_energy() > 0.0);
    }

    #[test]
    fn checkpoint_restart_resumes_identically() {
        let p = std::env::temp_dir().join(format!("coord_ckpt_{}", std::process::id()));
        let tree = SpaceTree::full(BBox::unit(), 1);
        let mut sim = Simulation::new(tree, 2, DomainBc::channel(0.8, 300.0), params(32.0));
        sim.init_temperature(300.0);
        for _ in 0..3 {
            sim.step(&RustBackend);
        }
        let io = ParallelIo::new(
            crate::cluster::Machine::local(),
            crate::cluster::IoTuning::default(),
            2,
        );
        let mut f = crate::h5lite::H5File::create(&p, 1).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 2).unwrap();
        sim.write_checkpoint(&mut f, &io).unwrap();
        // continue the original
        let rep_orig = sim.step(&RustBackend);

        // restart from file and take the same step
        let snap = iokernel::read_snapshot(&f, sim.t - 0.002).unwrap();
        let mut sim2 = Simulation::from_snapshot(snap, DomainBc::channel(0.8, 300.0));
        sim2.params = sim.params; // dt etc. identical (common group loses h)
        let rep_restart = sim2.step(&RustBackend);

        // same physics: kinetic energy matches to f32 noise
        let ke1 = sim.kinetic_energy();
        let ke2 = sim2.kinetic_energy();
        assert!(
            (ke1 - ke2).abs() <= 1e-7 * ke1.abs().max(1e-12),
            "ke {ke1} vs {ke2} (orig step {:?}, restart step {:?})",
            rep_orig.step,
            rep_restart.step
        );
        std::fs::remove_file(&p).ok();
    }
}
