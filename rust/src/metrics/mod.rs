//! Lightweight runtime metrics: named counters and duration accumulators
//! used by the coordinator and the bench harness (stand-in for a metrics
//! crate; everything is plain atomics so it can be shared across the
//! collector/steering threads).

use crate::sync::{LockRank, OrderedMutex};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Canonical metric names shared across modules, so tests and the bench
/// harness assert against one spelling instead of scattered literals.
pub mod names {
    /// `LodIndex`/topology parses performed by a read session — the
    /// amortisation the `window::SnapshotReader` exists for: exactly 1 per
    /// session lifetime, however many queries it serves (the per-call free
    /// functions paid one per call).
    pub const READER_INDEX_BUILDS: &str = "reader.index_builds";
    /// Bytes read to build the session's topology + LOD indexes (paid once
    /// at open).
    pub const READER_INDEX_BYTES: &str = "reader.index_bytes";
    /// Window/budgeted/progressive queries served by a read session.
    pub const READER_QUERIES: &str = "reader.queries";
    /// Grids returned across all of a session's queries.
    pub const READER_GRIDS: &str = "reader.grids";
    /// Logical cell-data payload bytes served across a session's queries.
    pub const READER_PAYLOAD_BYTES: &str = "reader.payload_bytes";
    /// Chunk reads that *coalesced* onto another session's in-flight decode
    /// of the same chunk instead of decoding it again — the shared cache's
    /// single-flight dedup under concurrent overlapping queries.
    pub const READER_COALESCED: &str = "reader.coalesced";
    /// Session opens served from a pool's shared parsed topology/`LodIndex`
    /// (O(1) — no index bytes read, no parse) instead of a fresh build.
    pub const READER_SHARED_OPENS: &str = "reader.shared_opens";
    /// Connections a `window::Collector` accepted and handed to a worker.
    pub const COLLECTOR_SESSIONS: &str = "collector.sessions";
    /// Window/LOD requests served across all collector connections.
    pub const COLLECTOR_QUERIES: &str = "collector.queries";
    /// Gauge: image pages dirtied since the last durability barrier of the
    /// snapshot file's paged backend (0 on the direct backend).
    pub const H5_DIRTY_PAGES: &str = "h5.dirty_pages";
    /// Gauge: cumulative bytes the background flusher has written to disk.
    pub const H5_FLUSH_BYTES: &str = "h5.flush_bytes";
    /// Gauge: estimated seconds of flush backlog — queued-but-unflushed
    /// bytes divided by the flusher's observed disk bandwidth.
    pub const H5_FLUSH_BACKLOG_SECONDS: &str = "h5.flush_backlog_seconds";
    /// Times the `window::Collector` accept loop found its dispatch
    /// backlog full and paused admitting sessions (counted once per
    /// saturation episode, with a log line) — the worker pool is saturated
    /// and would-be persistent sessions are waiting in the kernel's accept
    /// backlog (PR-6 caveat made visible; pair with `collector.sessions`
    /// for the admission rate).
    pub const COLLECTOR_SESSIONS_REJECTED: &str = "collector.sessions_rejected";
    /// Gauge: live `stream::EpochPublisher` subscribers.
    pub const STREAM_SUBSCRIBERS: &str = "stream.subscribers";
    /// Gauge: slowest subscriber's backlog in *epochs* (queued superblock
    /// flips it has not yet been sent).
    pub const STREAM_LAG_EPOCHS: &str = "stream.lag_epochs";
    /// Gauge: slowest subscriber's backlog in queued payload bytes.
    pub const STREAM_LAG_BYTES: &str = "stream.lag_bytes";
    /// Distinct epoch deliveries merged away (coalesce policy) or
    /// discarded by disconnecting a slow subscriber — each one is an epoch
    /// a consumer missed seeing individually. A commit's footer batch
    /// coalescing into its own flip batch is not counted.
    pub const STREAM_DROPPED_BATCHES: &str = "stream.dropped_batches";
}

/// A set of named counters (u64), timers (accumulated nanoseconds) and
/// gauges (last-written f64 samples).
///
/// The three registry maps share [`LockRank::MetricsRegistry`] — the
/// global leaf rank: metrics are recorded from under locks all over the
/// crate, and no method here holds two registry maps at once (report()
/// walks them strictly sequentially).
pub struct Metrics {
    counters: OrderedMutex<BTreeMap<String, AtomicU64>>,
    timers: OrderedMutex<BTreeMap<String, AtomicU64>>,
    /// f64 samples stored as raw bits so gauges share the atomic plumbing.
    gauges: OrderedMutex<BTreeMap<String, AtomicU64>>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            counters: OrderedMutex::new(LockRank::MetricsRegistry, BTreeMap::new()),
            timers: OrderedMutex::new(LockRank::MetricsRegistry, BTreeMap::new()),
            gauges: OrderedMutex::new(LockRank::MetricsRegistry, BTreeMap::new()),
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(v, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Time a closure, accumulating under `name`.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let ns = t0.elapsed().as_nanos() as u64;
        self.add_ns(name, ns);
        out
    }

    /// Accumulate an externally measured duration (nanoseconds) under
    /// `name` — used where the timed region spans threads (e.g. the
    /// aggregators' chunk-codec time in [`crate::pario`]).
    pub fn add_ns(&self, name: &str, ns: u64) {
        let mut m = self.timers.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(ns, Ordering::Relaxed);
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.timers
            .lock()
            .unwrap()
            .get(name)
            .map(|a| a.load(Ordering::Relaxed) as f64 / 1e9)
            .unwrap_or(0.0)
    }

    /// Set a gauge to the latest sample (unlike counters, gauges overwrite:
    /// they report *current* state — backlog depth, dirty pages — not an
    /// accumulation).
    pub fn set_gauge(&self, name: &str, v: f64) {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|a| f64::from_bits(a.load(Ordering::Relaxed)))
            .unwrap_or(0.0)
    }

    /// Snapshot of every counter (name → value), for test assertions and
    /// bench tables that want the whole set rather than one name.
    pub fn counters(&self) -> BTreeMap<String, u64> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Render all metrics as sorted `name value` lines.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!(
                "timer   {k} {:.6}s\n",
                v.load(Ordering::Relaxed) as f64 / 1e9
            ));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!(
                "gauge   {k} {:.6}\n",
                f64::from_bits(v.load(Ordering::Relaxed))
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add("steps", 1);
        m.add("steps", 2);
        assert_eq!(m.counter("steps"), 3);
        assert_eq!(m.counter("absent"), 0);
    }

    #[test]
    fn timers_accumulate_and_return_value() {
        let m = Metrics::new();
        let x = m.time("work", || 42);
        assert_eq!(x, 42);
        assert!(m.seconds("work") >= 0.0);
        m.time("work", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(m.seconds("work") >= 0.002);
    }

    #[test]
    fn report_lists_everything() {
        let m = Metrics::new();
        m.add("a", 1);
        m.time("b", || ());
        let rep = m.report();
        assert!(rep.contains("counter a 1"));
        assert!(rep.contains("timer   b"));
    }

    #[test]
    fn counters_snapshot_returns_all_values() {
        let m = Metrics::new();
        m.add(names::READER_QUERIES, 3);
        m.add("other", 1);
        let snap = m.counters();
        assert_eq!(snap.get(names::READER_QUERIES), Some(&3));
        assert_eq!(snap.get("other"), Some(&1));
        assert_eq!(snap.len(), 2);
    }

    #[test]
    fn add_ns_accumulates_into_timers() {
        let m = Metrics::new();
        m.add_ns("io", 500_000_000);
        m.add_ns("io", 250_000_000);
        assert!((m.seconds("io") - 0.75).abs() < 1e-9);
    }

    #[test]
    fn gauges_overwrite_and_report() {
        let m = Metrics::new();
        m.set_gauge(names::H5_DIRTY_PAGES, 3.0);
        m.set_gauge(names::H5_DIRTY_PAGES, 1.5);
        assert_eq!(m.gauge(names::H5_DIRTY_PAGES), 1.5, "gauges must overwrite");
        assert_eq!(m.gauge("absent"), 0.0);
        m.set_gauge(names::H5_FLUSH_BACKLOG_SECONDS, 0.25);
        let rep = m.report();
        assert!(rep.contains("gauge   h5.dirty_pages 1.500000"), "{rep}");
        assert!(
            rep.contains("gauge   h5.flush_backlog_seconds 0.250000"),
            "{rep}"
        );
    }

    #[test]
    fn thread_safe_updates() {
        let m = Metrics::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..100 {
                        m.add("n", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("n"), 800);
    }
}
