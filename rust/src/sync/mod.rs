//! Concurrency-analysis layer: ranked locks and a bounded-interleaving
//! model checker.
//!
//! Six subsystems of this crate interact through ~200 lock/atomic sites —
//! the pario aggregators, the [`crate::h5lite::store`] background flusher,
//! the epoch-pin retire queue, the shared-cache single-flight, the
//! `window::Collector` worker pool and the `stream` publisher/sender
//! threads. This module makes how they *compose* a build/test-time
//! property instead of a code-review hope, the same way
//! `H5File::verify()` did for space accounting:
//!
//! * **Ranked locks** ([`OrderedMutex`], [`OrderedRwLock`],
//!   [`OrderedCondvar`]): every named lock family carries a static
//!   [`LockRank`]; debug builds keep a thread-local stack of held ranks
//!   and panic the moment any thread acquires out of rank order — i.e.
//!   the moment a lock-order cycle (deadlock) becomes *possible*, on any
//!   schedule, not the rare schedule where it bites. Release builds
//!   compile to a transparent passthrough over [`std::sync::Mutex`] /
//!   [`std::sync::RwLock`] — the guard types *are* the std guards, zero
//!   wrappers, zero overhead.
//! * **Model checker** ([`model`]): a deterministic cooperative scheduler
//!   exploring every interleaving of small protocol models (up to a
//!   preemption bound) as ordinary `cargo test`s. The three hairiest
//!   protocols of the crate are expressed as models in [`protocols`]:
//!   commit-barrier ordering vs. the draining flusher with injected
//!   faults, epoch-pin retire/park/release vs. concurrent rewrite, and
//!   publisher subscriber-seeding vs. the durable watermark.
//!
//! The full lock-family → rank table, with who acquires what while
//! holding what, lives in `CONCURRENCY.md` at the repo root.

use std::sync::{Condvar, LockResult, Mutex, MutexGuard, PoisonError, RwLock};

pub mod model;
pub mod protocols;

/// Static acquisition rank of every named lock family in the crate.
///
/// The invariant enforced in debug builds: a thread may only acquire a
/// lock whose rank is **strictly greater** than every rank it already
/// holds (same-rank acquisition of a *different instance* is allowed only
/// for families in the audited exception table — see
/// [`LockRank::allows_same_rank`]). Numeric gaps leave room to slot new
/// families without renumbering.
///
/// The ordering encodes the real chains observed in the code, outermost
/// first; see `CONCURRENCY.md` for the per-family justification.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Hash)]
#[repr(u16)]
pub enum LockRank {
    /// `window::Dispatcher.queue` — accepted connections awaiting a worker.
    CollectorDispatch = 10,
    /// The `RwLock<Simulation>` behind `window::Backend::Live`.
    SimulationState = 20,
    /// `window::FollowerState.cur` — the follower's mirror re-open handle.
    FollowerCurrent = 30,
    /// `window::ReaderPool.cores` — the shared parsed-core map (held
    /// across `ReaderCore::build`, deliberately).
    ReaderPoolCores = 40,
    /// `stream::StreamSubscriber.state` — apply progress + liveness.
    SubscriberState = 50,
    /// `pario::ParallelIo.publisher` — the attached epoch publisher.
    ParioPublisher = 60,
    /// `pario::ParallelIo.lock` — the paper's file-locking stand-in,
    /// held across whole `H5File` writes when `tuning.file_locking`.
    ParioFileLock = 70,
    /// `h5lite::H5File.rmw` — serialises partial-chunk read-modify-write
    /// (held across chunk reads *and* the re-encode write-back).
    FileRmw = 80,
    /// `h5lite::H5File.chunks` — the chunk extent registry.
    FileChunks = 90,
    /// `h5lite::H5File.contig` — epoch-versioned contiguous write-aside
    /// state (held across relocation copies and extent allocation).
    FileContig = 100,
    /// `h5lite::H5File.data_end` — the append allocator bump pointer
    /// (held across `Store::set_len_min`).
    FileDataEnd = 110,
    /// `h5lite::SpaceShared.pins` — the epoch-pin table. Held across the
    /// commit's epoch-bump + park-vs-free decision, so ranked below
    /// `parked`/`free`.
    SpacePins = 120,
    /// `h5lite::SpaceShared.pending` — extents retired this epoch.
    SpacePending = 130,
    /// `h5lite::SpaceShared.parked` — the generation-tagged retire queue.
    SpaceParked = 140,
    /// `h5lite::SpaceShared.free` — the allocatable free list.
    SpaceFree = 150,
    /// `h5lite::H5File.committed_footer` — the live footer extent.
    FileCommittedFooter = 160,
    /// `h5lite::H5File.cache` — the private decoded-chunk cache.
    FileCache = 170,
    /// One shard of `h5lite::SharedChunkCache` (16 instances; in the
    /// same-rank exception table — the sharded family is the audited
    /// same-rank pattern, though no current path nests two shards).
    CacheShard = 180,
    /// `h5lite::SharedChunkCache.files` — path → file-key registry.
    CacheFiles = 190,
    /// `h5lite::Inflight.state` — a single-flight decode slot (resolved
    /// by the leader while its shard lock is held).
    FlightState = 200,
    /// `h5lite::store::PagedImage.state` — pages + dirty ranges.
    StoreState = 210,
    /// `h5lite::store::FlushShared.queue` — the ordered batch queue
    /// (`BatchSink::on_batch` fires under it, so it ranks below the
    /// publisher's registry).
    StoreQueue = 220,
    /// `h5lite::store::FlushShared.sink` — the registered batch sink
    /// (cloned out under the queue lock).
    StoreSink = 230,
    /// `h5lite::store::PagedImage.flusher` — the flusher join handle.
    StoreFlusherHandle = 240,
    /// `stream::PubShared.inner` — subscriber registry + retained frames.
    PubInner = 250,
    /// One subscriber's `stream::SubSlot` queue (under `PubInner` on the
    /// publish/registration path).
    SubSlot = 260,
    /// `stream::EpochPublisher.accept` — the accept-loop join handle.
    PubAccept = 270,
    /// `stream::StreamSubscriber.apply` — the apply-loop join handle.
    SubApplyHandle = 280,
    /// `pario` per-call error collectors (taken under [`ParioFileLock`]).
    ParioErrors = 290,
    /// The three `metrics::Metrics` registries — the global leaf: metrics
    /// are recorded from under almost anything (publisher inner, reader
    /// pool map, …) and never acquire anything themselves.
    MetricsRegistry = 300,
}

impl LockRank {
    /// Audited same-rank exception table: families whose *distinct
    /// instances* may be held together at one rank. Only the sharded
    /// cache qualifies today — 16 peer shards of one
    /// `SharedChunkCache`, where no code path nests two shards but the
    /// family is structurally many-instances-one-rank. Everything else
    /// is strict: same rank + any held instance = panic (which also
    /// catches same-instance recursion, a guaranteed std deadlock).
    pub fn allows_same_rank(self) -> bool {
        matches!(self, LockRank::CacheShard)
    }
}

// ---------------------------------------------------------------------------
// debug/test builds: rank-checked wrappers
// ---------------------------------------------------------------------------

#[cfg(debug_assertions)]
mod rank {
    use super::LockRank;
    use std::cell::RefCell;

    thread_local! {
        /// Ranks (and lock-instance addresses) this thread currently
        /// holds, in acquisition order. Guards may drop out of order, so
        /// checks compare against the *maximum* held rank, and release
        /// removes by identity.
        static HELD: RefCell<Vec<(LockRank, usize)>> = const { RefCell::new(Vec::new()) };
    }

    /// Validate and record an acquisition. Panics on rank-order violation
    /// — i.e. whenever a deadlock between this lock family and a held one
    /// is possible on *some* schedule.
    pub fn acquire(rank: LockRank, id: usize) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(&(top, top_id)) = held.iter().max_by_key(|&&(r, _)| r) {
                let ok = rank > top
                    || (rank == top
                        && rank.allows_same_rank()
                        && held.iter().all(|&(r, i)| r != rank || i != id));
                assert!(
                    ok,
                    "lock rank violation: acquiring {rank:?} (instance {id:#x}) while \
                     holding {held:?} (max {top:?} @ {top_id:#x}); acquisition order \
                     must strictly ascend — see CONCURRENCY.md",
                    held = held.iter().map(|&(r, _)| r).collect::<Vec<_>>(),
                );
            }
            held.push((rank, id));
        });
    }

    /// Remove a held entry by identity (guards can drop out of order).
    pub fn release(rank: LockRank, id: usize) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(pos) = held.iter().rposition(|&(r, i)| r == rank && i == id) {
                held.remove(pos);
            }
        });
    }

    /// Re-record a rank after a condvar wait re-acquired its mutex. The
    /// ranks held across the wait were all below `rank` when it was first
    /// acquired and the thread cannot have acquired more while blocked,
    /// so this re-checks the same invariant acquire() did.
    pub fn reacquire(rank: LockRank, id: usize) {
        acquire(rank, id);
    }

    /// Test hook: the ranks this thread currently holds, in acquisition
    /// order.
    pub fn held_ranks() -> Vec<LockRank> {
        HELD.with(|h| h.borrow().iter().map(|&(r, _)| r).collect())
    }
}

/// Test hook (debug builds): ranks the current thread holds right now.
#[cfg(debug_assertions)]
pub fn held_ranks() -> Vec<LockRank> {
    rank::held_ranks()
}

/// A [`std::sync::Mutex`] carrying a static [`LockRank`]. Debug builds
/// assert rank-ascending acquisition; release builds are a transparent
/// passthrough (the guard **is** [`MutexGuard`]).
pub struct OrderedMutex<T: ?Sized> {
    rank: LockRank,
    inner: Mutex<T>,
}

/// A [`std::sync::RwLock`] carrying a static [`LockRank`]; read and
/// write acquisitions both participate in the rank order.
pub struct OrderedRwLock<T: ?Sized> {
    rank: LockRank,
    inner: RwLock<T>,
}

/// A [`Condvar`] aware of [`OrderedMutex`] guards: waiting releases the
/// guard's rank for the blocked stretch and re-records it (re-checking
/// the order) when the wait returns.
pub struct OrderedCondvar {
    inner: Condvar,
}

impl Default for OrderedCondvar {
    fn default() -> OrderedCondvar {
        OrderedCondvar::new()
    }
}

impl OrderedCondvar {
    pub const fn new() -> OrderedCondvar {
        OrderedCondvar { inner: Condvar::new() }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

#[cfg(debug_assertions)]
mod checked {
    use super::*;
    use std::fmt;
    use std::ops::{Deref, DerefMut};
    use std::sync::{RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult};
    use std::time::Duration;

    /// Debug-build guard: wraps the std guard and pops its rank on drop.
    pub struct OrderedMutexGuard<'a, T: ?Sized> {
        // `Option` so `OrderedCondvar::wait` can take the std guard out
        // without running this wrapper's release logic.
        pub(super) inner: Option<MutexGuard<'a, T>>,
        pub(super) rank: LockRank,
        pub(super) id: usize,
    }

    impl<T: ?Sized> Deref for OrderedMutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().unwrap()
        }
    }

    impl<T: ?Sized> DerefMut for OrderedMutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().unwrap()
        }
    }

    impl<T: ?Sized + fmt::Debug> fmt::Debug for OrderedMutexGuard<'_, T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            fmt::Debug::fmt(&**self, f)
        }
    }

    impl<T: ?Sized> Drop for OrderedMutexGuard<'_, T> {
        fn drop(&mut self) {
            if self.inner.take().is_some() {
                rank::release(self.rank, self.id);
            }
        }
    }

    pub struct OrderedReadGuard<'a, T: ?Sized> {
        pub(super) inner: Option<RwLockReadGuard<'a, T>>,
        pub(super) rank: LockRank,
        pub(super) id: usize,
    }

    impl<T: ?Sized> Deref for OrderedReadGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().unwrap()
        }
    }

    impl<T: ?Sized> Drop for OrderedReadGuard<'_, T> {
        fn drop(&mut self) {
            if self.inner.take().is_some() {
                rank::release(self.rank, self.id);
            }
        }
    }

    pub struct OrderedWriteGuard<'a, T: ?Sized> {
        pub(super) inner: Option<RwLockWriteGuard<'a, T>>,
        pub(super) rank: LockRank,
        pub(super) id: usize,
    }

    impl<T: ?Sized> Deref for OrderedWriteGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().unwrap()
        }
    }

    impl<T: ?Sized> DerefMut for OrderedWriteGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().unwrap()
        }
    }

    impl<T: ?Sized> Drop for OrderedWriteGuard<'_, T> {
        fn drop(&mut self) {
            if self.inner.take().is_some() {
                rank::release(self.rank, self.id);
            }
        }
    }

    impl<T> OrderedMutex<T> {
        pub fn new(rank: LockRank, value: T) -> OrderedMutex<T> {
            OrderedMutex { rank, inner: Mutex::new(value) }
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.inner.into_inner()
        }
    }

    impl<T: ?Sized> OrderedMutex<T> {
        pub fn lock(&self) -> LockResult<OrderedMutexGuard<'_, T>> {
            let id = self as *const OrderedMutex<T> as *const () as usize;
            // record BEFORE blocking: the whole point is to flag the
            // would-deadlock acquisition instead of hanging in it
            rank::acquire(self.rank, id);
            let wrap = |g| OrderedMutexGuard { inner: Some(g), rank: self.rank, id };
            match self.inner.lock() {
                Ok(g) => Ok(wrap(g)),
                Err(p) => Err(PoisonError::new(wrap(p.into_inner()))),
            }
        }
    }

    impl<T> OrderedRwLock<T> {
        pub fn new(rank: LockRank, value: T) -> OrderedRwLock<T> {
            OrderedRwLock { rank, inner: RwLock::new(value) }
        }
    }

    impl<T: ?Sized> OrderedRwLock<T> {
        pub fn read(&self) -> LockResult<OrderedReadGuard<'_, T>> {
            let id = self as *const OrderedRwLock<T> as *const () as usize;
            rank::acquire(self.rank, id);
            let wrap = |g| OrderedReadGuard { inner: Some(g), rank: self.rank, id };
            match self.inner.read() {
                Ok(g) => Ok(wrap(g)),
                Err(p) => Err(PoisonError::new(wrap(p.into_inner()))),
            }
        }

        pub fn write(&self) -> LockResult<OrderedWriteGuard<'_, T>> {
            let id = self as *const OrderedRwLock<T> as *const () as usize;
            rank::acquire(self.rank, id);
            let wrap = |g| OrderedWriteGuard { inner: Some(g), rank: self.rank, id };
            match self.inner.write() {
                Ok(g) => Ok(wrap(g)),
                Err(p) => Err(PoisonError::new(wrap(p.into_inner()))),
            }
        }
    }

    impl OrderedCondvar {
        pub fn wait<'a, T>(
            &self,
            mut guard: OrderedMutexGuard<'a, T>,
        ) -> LockResult<OrderedMutexGuard<'a, T>> {
            let (rank, id) = (guard.rank, guard.id);
            let std_guard = guard.inner.take().unwrap();
            // the mutex is released for the blocked stretch; so is its
            // rank — the thread holds nothing it could deadlock through
            rank::release(rank, id);
            let res = self.inner.wait(std_guard);
            rank::reacquire(rank, id);
            let wrap = |g| OrderedMutexGuard { inner: Some(g), rank, id };
            match res {
                Ok(g) => Ok(wrap(g)),
                Err(p) => Err(PoisonError::new(wrap(p.into_inner()))),
            }
        }

        pub fn wait_timeout<'a, T>(
            &self,
            mut guard: OrderedMutexGuard<'a, T>,
            dur: Duration,
        ) -> LockResult<(OrderedMutexGuard<'a, T>, WaitTimeoutResult)> {
            let (rank, id) = (guard.rank, guard.id);
            let std_guard = guard.inner.take().unwrap();
            rank::release(rank, id);
            let res = self.inner.wait_timeout(std_guard, dur);
            rank::reacquire(rank, id);
            let wrap = |g| OrderedMutexGuard { inner: Some(g), rank, id };
            match res {
                Ok((g, t)) => Ok((wrap(g), t)),
                Err(p) => {
                    let (g, t) = p.into_inner();
                    Err(PoisonError::new((wrap(g), t)))
                }
            }
        }
    }
}

#[cfg(debug_assertions)]
pub use checked::{OrderedMutexGuard, OrderedReadGuard, OrderedWriteGuard};

// ---------------------------------------------------------------------------
// release builds: transparent passthrough — the guards ARE the std guards
// ---------------------------------------------------------------------------

#[cfg(not(debug_assertions))]
mod passthrough {
    use super::*;
    use std::sync::WaitTimeoutResult;
    use std::time::Duration;

    impl<T> OrderedMutex<T> {
        #[inline]
        pub fn new(rank: LockRank, value: T) -> OrderedMutex<T> {
            OrderedMutex { rank, inner: Mutex::new(value) }
        }

        #[inline]
        pub fn into_inner(self) -> LockResult<T> {
            self.inner.into_inner()
        }
    }

    impl<T: ?Sized> OrderedMutex<T> {
        #[inline]
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            let _ = self.rank;
            self.inner.lock()
        }
    }

    impl<T> OrderedRwLock<T> {
        #[inline]
        pub fn new(rank: LockRank, value: T) -> OrderedRwLock<T> {
            OrderedRwLock { rank, inner: RwLock::new(value) }
        }
    }

    impl<T: ?Sized> OrderedRwLock<T> {
        #[inline]
        pub fn read(&self) -> LockResult<std::sync::RwLockReadGuard<'_, T>> {
            let _ = self.rank;
            self.inner.read()
        }

        #[inline]
        pub fn write(&self) -> LockResult<std::sync::RwLockWriteGuard<'_, T>> {
            self.inner.write()
        }
    }

    impl OrderedCondvar {
        #[inline]
        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            self.inner.wait(guard)
        }

        #[inline]
        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            self.inner.wait_timeout(guard, dur)
        }
    }
}

/// Release builds: the guard is exactly [`MutexGuard`] — no wrapper.
#[cfg(not(debug_assertions))]
pub type OrderedMutexGuard<'a, T> = MutexGuard<'a, T>;
/// Release builds: the guard is exactly [`std::sync::RwLockReadGuard`].
#[cfg(not(debug_assertions))]
pub type OrderedReadGuard<'a, T> = std::sync::RwLockReadGuard<'a, T>;
/// Release builds: the guard is exactly [`std::sync::RwLockWriteGuard`].
#[cfg(not(debug_assertions))]
pub type OrderedWriteGuard<'a, T> = std::sync::RwLockWriteGuard<'a, T>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_order_acquisition_is_clean() {
        let low = OrderedMutex::new(LockRank::FileChunks, 1u32);
        let high = OrderedMutex::new(LockRank::StoreState, 2u32);
        let a = low.lock().unwrap();
        let b = high.lock().unwrap();
        assert_eq!(*a + *b, 3);
        #[cfg(debug_assertions)]
        assert_eq!(held_ranks(), vec![LockRank::FileChunks, LockRank::StoreState]);
        drop(b);
        drop(a);
        #[cfg(debug_assertions)]
        assert!(held_ranks().is_empty());
    }

    #[test]
    fn out_of_order_release_keeps_the_stack_consistent() {
        let low = OrderedMutex::new(LockRank::FileRmw, ());
        let mid = OrderedMutex::new(LockRank::FileChunks, ());
        let high = OrderedMutex::new(LockRank::StoreState, ());
        let a = low.lock().unwrap();
        let b = mid.lock().unwrap();
        drop(a); // out of order: release the outermost first
        let c = high.lock().unwrap(); // still fine: max held is FileChunks
        drop(b);
        drop(c);
        #[cfg(debug_assertions)]
        assert!(held_ranks().is_empty());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock rank violation")]
    fn deliberate_inversion_panics_in_debug_builds() {
        let low = OrderedMutex::new(LockRank::StoreQueue, ());
        let high = OrderedMutex::new(LockRank::PubInner, ());
        let _g = high.lock().unwrap();
        let _bad = low.lock().unwrap(); // StoreQueue < PubInner: inversion
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock rank violation")]
    fn same_rank_without_exception_panics() {
        // two Metrics registries at one strict rank must never nest
        let a = OrderedMutex::new(LockRank::MetricsRegistry, ());
        let b = OrderedMutex::new(LockRank::MetricsRegistry, ());
        let _g = a.lock().unwrap();
        let _bad = b.lock().unwrap();
    }

    #[test]
    fn sharded_same_rank_exception_allows_distinct_instances() {
        let a = OrderedMutex::new(LockRank::CacheShard, 1);
        let b = OrderedMutex::new(LockRank::CacheShard, 2);
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap(); // distinct instance at an excepted rank
        assert_eq!(*ga + *gb, 3);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "lock rank violation")]
    fn same_instance_reentry_panics_even_on_excepted_rank() {
        // would be a guaranteed std::sync::Mutex self-deadlock — the rank
        // layer flags it instead of hanging
        let a = OrderedMutex::new(LockRank::CacheShard, ());
        let _g = a.lock().unwrap();
        let _dead = a.lock().unwrap();
    }

    #[test]
    fn condvar_wait_releases_and_rerecords_the_rank() {
        use std::sync::Arc;
        let pair = Arc::new((
            OrderedMutex::new(LockRank::StoreQueue, false),
            OrderedCondvar::new(),
        ));
        let p2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock().unwrap();
            while !*g {
                g = cv.wait(g).unwrap();
            }
            // after the wait the rank must be held again
            #[cfg(debug_assertions)]
            assert_eq!(held_ranks(), vec![LockRank::StoreQueue]);
        });
        let (m, cv) = &*pair;
        *m.lock().unwrap() = true;
        cv.notify_all();
        t.join().unwrap();
    }

    #[test]
    fn rwlock_participates_in_rank_order() {
        let sim = OrderedRwLock::new(LockRank::SimulationState, 7u32);
        let pool = OrderedMutex::new(LockRank::ReaderPoolCores, ());
        let r = sim.read().unwrap();
        let _p = pool.lock().unwrap(); // 20 < 40: fine
        assert_eq!(*r, 7);
        drop(r);
        let mut w = sim.write().unwrap();
        *w += 1;
        assert_eq!(*w, 8);
    }

    /// Release passthrough adds no wrappers: the guard type IS the std
    /// guard, and the lock adds nothing beyond the rank tag. Compiled
    /// only into release test runs (`cargo test --release`).
    #[cfg(not(debug_assertions))]
    #[test]
    fn release_passthrough_guards_are_std_guards() {
        let m = OrderedMutex::new(LockRank::StoreQueue, 5u64);
        // compile-time proof: the guard coerces to MutexGuard because it
        // *is* one
        let g: std::sync::MutexGuard<'_, u64> = m.lock().unwrap();
        assert_eq!(*g, 5);
        drop(g);
        let rw = OrderedRwLock::new(LockRank::SimulationState, 1u8);
        let r: std::sync::RwLockReadGuard<'_, u8> = rw.read().unwrap();
        assert_eq!(*r, 1);
    }

    #[test]
    fn poisoned_ordered_mutex_reports_like_std() {
        use std::sync::Arc;
        let m = Arc::new(OrderedMutex::new(LockRank::StoreQueue, 1u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "poison must propagate through the wrapper");
        // and the rank stack survives: a poisoned acquire still balances
        let _ = m.lock().map(|_| ()).map_err(|_| ());
        #[cfg(debug_assertions)]
        assert!(held_ranks().is_empty());
    }
}
