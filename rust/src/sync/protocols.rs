//! The crate's three hairiest concurrent protocols, expressed as
//! [`Model`]s and exhaustively explored by the [`Checker`] as ordinary
//! `cargo test`s.
//!
//! Each protocol comes in two variants: the **fixed** shape matching the
//! shipped code (the invariant must hold on *every* interleaving), and a
//! **buggy** shape matching the pre-fix / naive ordering (the checker
//! must *find* the violating schedule — proving the test has teeth and
//! pinning the race so it cannot be reintroduced).
//!
//! 1. [`CommitFlush`] — the crash-consistency spine of
//!    `H5File::commit`: append footer → durability barrier → superblock
//!    flip → barrier, racing the background flusher with a fault
//!    injected at every possible point. Invariant: the superblock never
//!    points at an epoch whose footer is not fully durable (the
//!    recoverable-epoch floor).
//! 2. [`PinRetire`] — `SpaceShared` epoch pinning vs. commit-time
//!    retire/park/free. Invariant: no extent is freed while a pin at or
//!    below its retire tag exists. The buggy variant models the original
//!    `pin_epoch` (epoch load and pin insert as two steps — the race
//!    fixed in this PR); the fixed variant holds the pins lock across
//!    both, as the code now does.
//! 3. [`PubSeed`] — `EpochPublisher` subscriber seeding vs. the durable
//!    watermark advancing and pruning retained frames. Invariant: a
//!    subscriber seeded at watermark `d` receives every sequence in
//!    `(d, last_published]` with no gap. The fixed variant snapshots
//!    retained frames and registers in one critical section (as
//!    `accept_loop` does under `PubShared.inner`); the buggy variant
//!    splits snapshot and registration.

use super::model::{Checker, Model, Step};

// ---------------------------------------------------------------------------
// (a) commit barrier ordering vs. draining flusher with injected faults
// ---------------------------------------------------------------------------

/// How many queued write ops make up one epoch's footer (footer record +
/// free-record block in the real layout).
const FOOTER_PARTS: u8 = 2;
/// Epochs the writer commits.
const COMMIT_EPOCHS: u64 = 2;

/// Ops the writer enqueues to the flusher, in FIFO order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum FlushOp {
    /// One part of epoch `e`'s footer image.
    FooterPart(u64),
    /// The superblock flip making epoch `e` the committed one.
    Flip(u64),
}

#[derive(Clone)]
pub struct CommitFlushState {
    /// FIFO batch queue between writer and flusher (`FlushShared.queue`).
    queue: Vec<FlushOp>,
    /// Durable footer parts landed per epoch (index = epoch).
    footer_parts: [u8; (COMMIT_EPOCHS + 1) as usize],
    /// The epoch the durable superblock points at (0 = seed image).
    flip: u64,
    /// Writer program counter: 5 phases per epoch.
    writer_pc: u64,
    writer_done: bool,
    /// Fault injection: the flusher thread has died mid-drain.
    flusher_dead: bool,
    fault_fired: bool,
}

/// Model (a): the commit protocol vs. the flusher, plus a fault thread
/// that kills the flusher at every possible drain point (scheduling the
/// fault last ≡ the fault-free run, so that case is covered too).
///
/// `buggy = true` enqueues the superblock flip *before* the footer parts
/// with no intervening barrier — the write-reordering hazard the two
/// durability barriers in `H5File::commit` exist to prevent.
pub struct CommitFlush {
    pub buggy: bool,
}

const W_PHASES: u64 = 5; // part, part, barrier-wait, flip, barrier-wait

impl Model for CommitFlush {
    type State = CommitFlushState;

    fn init(&self) -> CommitFlushState {
        CommitFlushState {
            queue: Vec::new(),
            footer_parts: [0; (COMMIT_EPOCHS + 1) as usize],
            flip: 0,
            writer_pc: 0,
            writer_done: false,
            flusher_dead: false,
            fault_fired: false,
        }
    }

    fn threads(&self) -> usize {
        3 // 0 = writer, 1 = flusher, 2 = fault injector
    }

    fn step(&self, tid: usize, s: &mut CommitFlushState) -> Step {
        match tid {
            // writer: commit() — footer parts, barrier, flip, barrier
            0 => {
                if s.writer_done {
                    return Step::Done;
                }
                if s.flusher_dead {
                    // barrier()/wait_durable report the dead flusher as an
                    // error; the commit aborts. Disk keeps whatever landed.
                    s.writer_done = true;
                    return Step::Done;
                }
                let epoch = s.writer_pc / W_PHASES + 1;
                let phase = s.writer_pc % W_PHASES;
                // the buggy ordering swaps the flip to the front of the
                // epoch's ops and drops the barrier between footer and flip
                let op = if self.buggy {
                    match phase {
                        0 => Some(FlushOp::Flip(epoch)),
                        1 | 2 => Some(FlushOp::FooterPart(epoch)),
                        _ => None, // phases 3,4: single trailing barrier
                    }
                } else {
                    match phase {
                        0 | 1 => Some(FlushOp::FooterPart(epoch)),
                        3 => Some(FlushOp::Flip(epoch)),
                        _ => None, // phases 2,4: durability barriers
                    }
                };
                match op {
                    Some(op) => s.queue.push(op),
                    None => {
                        // a durability barrier: block until the flusher
                        // has drained everything enqueued so far
                        if !s.queue.is_empty() {
                            return Step::Blocked;
                        }
                    }
                }
                s.writer_pc += 1;
                if s.writer_pc == COMMIT_EPOCHS * W_PHASES {
                    s.writer_done = true;
                    Step::Done
                } else {
                    Step::Progress
                }
            }
            // flusher: pop one op per step, apply it to the durable image
            1 => {
                if s.flusher_dead {
                    return Step::Done;
                }
                if s.queue.is_empty() {
                    return if s.writer_done { Step::Done } else { Step::Blocked };
                }
                match s.queue.remove(0) {
                    FlushOp::FooterPart(e) => s.footer_parts[e as usize] += 1,
                    FlushOp::Flip(e) => s.flip = e,
                }
                Step::Progress
            }
            // fault injector: one step, kills the flusher wherever the
            // scheduler placed it
            _ => {
                if !s.fault_fired {
                    s.fault_fired = true;
                    s.flusher_dead = true;
                }
                Step::Done
            }
        }
    }
}

/// The recoverable-epoch-floor invariant: recovery trusts the superblock
/// pointer, so it must never name an epoch whose footer is incomplete.
pub fn commit_flush_invariant(s: &CommitFlushState) -> Result<(), String> {
    if s.flip != 0 && s.footer_parts[s.flip as usize] != FOOTER_PARTS {
        return Err(format!(
            "superblock points at epoch {} but only {}/{} footer parts are durable — \
             recovery would read a torn footer",
            s.flip, s.footer_parts[s.flip as usize], FOOTER_PARTS
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// (b) epoch-pin retire/park/release vs. concurrent rewrite + pin drop
// ---------------------------------------------------------------------------

const PIN_COMMITS: usize = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ExtentStatus {
    /// Still the live extent of its object (not yet retired).
    Live,
    /// Retired at its tag epoch, parked pending pin release.
    Parked,
    /// Returned to the free list (reusable — a writer may overwrite it).
    Freed,
}

#[derive(Clone)]
pub struct PinRetireState {
    /// Allocator epoch clock (`SpaceShared.epoch`).
    epoch: u64,
    /// Outstanding pins: pinned-epoch values (at most one per reader here).
    pins: Vec<u64>,
    /// One extent retired per commit: (retire tag, status).
    extents: Vec<(u64, ExtentStatus)>,
    commits_done: usize,
    reader_pc: u8,
    /// Buggy variant only: the epoch value the reader loaded before its
    /// pin insert landed.
    reader_loaded: Option<u64>,
}

/// Model (b): a writer committing (retire an old extent, bump the epoch,
/// park-or-free, release parked) racing a reader that pins, reads, and
/// unpins.
///
/// `buggy = true` models the original `pin_epoch`: `epoch.load()` and
/// the pins-table insert as two separate steps, letting a full commit
/// slip between them — the freed-while-pinned race this PR fixes by
/// holding the pins lock across both sides.
pub struct PinRetire {
    pub buggy: bool,
}

fn min_pin(pins: &[u64]) -> Option<u64> {
    pins.iter().copied().min()
}

fn release_parked(s: &mut PinRetireState) {
    let floor = min_pin(&s.pins);
    for (tag, status) in s.extents.iter_mut() {
        if *status == ExtentStatus::Parked && floor.map_or(true, |f| *tag < f) {
            *status = ExtentStatus::Freed;
        }
    }
}

impl Model for PinRetire {
    type State = PinRetireState;

    fn init(&self) -> PinRetireState {
        PinRetireState {
            epoch: 0,
            pins: Vec::new(),
            extents: Vec::new(),
            commits_done: 0,
            reader_pc: 0,
            reader_loaded: None,
        }
    }

    fn threads(&self) -> usize {
        2 // 0 = committing writer, 1 = pinning reader
    }

    fn step(&self, tid: usize, s: &mut PinRetireState) -> Step {
        match tid {
            // writer: one commit per two steps — the commit tail (atomic:
            // the code holds SpaceShared.pins across it), then
            // release_parked
            0 => {
                if s.commits_done == PIN_COMMITS {
                    return Step::Done;
                }
                // commit tail under the pins lock: tag the retired extent
                // with the pre-bump epoch, bump, then park iff a pin at or
                // below the tag exists
                let tag = s.epoch;
                s.epoch += 1;
                let status = if min_pin(&s.pins).is_some_and(|p| p <= tag) {
                    ExtentStatus::Parked
                } else {
                    ExtentStatus::Freed
                };
                s.extents.push((tag, status));
                // then release_parked (the pins lock is dropped; a stale
                // floor is conservative — parked extents only outlive pins)
                release_parked(s);
                s.commits_done += 1;
                Step::Progress
            }
            // reader: pin → read → unpin
            _ => match (s.reader_pc, self.buggy) {
                // fixed pin_epoch: load + insert under one pins lock
                (0, false) => {
                    s.pins.push(s.epoch);
                    s.reader_pc = 2;
                    Step::Progress
                }
                // buggy pin_epoch: the epoch load…
                (0, true) => {
                    s.reader_loaded = Some(s.epoch);
                    s.reader_pc = 1;
                    Step::Progress
                }
                // …and the pins insert as a second, preemptible step
                (1, true) => {
                    s.pins.push(s.reader_loaded.take().unwrap());
                    s.reader_pc = 2;
                    Step::Progress
                }
                // the read itself: the invariant below is exactly the
                // property the read depends on, so this is a no-op here
                (2, _) => {
                    s.reader_pc = 3;
                    Step::Progress
                }
                // unpin: drop the pin, then release_parked (EpochPin::drop)
                (3, _) => {
                    s.pins.pop();
                    release_parked(s);
                    s.reader_pc = 4;
                    Step::Done
                }
                _ => Step::Done,
            },
        }
    }
}

/// No extent may be freed (hence reusable/overwritable) while a pin at
/// or below its retire tag is outstanding — a pinned reader's view must
/// stay byte-stable.
pub fn pin_retire_invariant(s: &PinRetireState) -> Result<(), String> {
    for &(tag, status) in &s.extents {
        if status == ExtentStatus::Freed {
            if let Some(p) = min_pin(&s.pins) {
                if p <= tag {
                    return Err(format!(
                        "extent retired at epoch {tag} is freed while a pin at epoch \
                         {p} <= {tag} is outstanding — the pinned reader can observe \
                         its bytes being overwritten"
                    ));
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// (c) publisher subscriber-seeding vs. durable-watermark advance
// ---------------------------------------------------------------------------

const PUB_SEQS: u64 = 3;

#[derive(Clone)]
pub struct PubSeedState {
    /// Highest sequence the writer has published.
    published: u64,
    /// Retained (not-yet-durable) frames (`PubInner.retained`).
    retained: Vec<u64>,
    /// Durable watermark the flusher has advanced to.
    durable: u64,
    /// Frames the subscriber has received (seed + live pushes).
    delivered: Vec<u64>,
    /// The watermark the subscriber was told it was seeded from.
    seed_from: u64,
    registered: bool,
    /// Buggy variant only: snapshot taken, registration still pending.
    pending_seed: Option<(Vec<u64>, u64)>,
    registrar_pc: u8,
}

/// Model (c): the writer publishing frames 1..=[`PUB_SEQS`] (retaining
/// each, and pushing to the registered subscriber), the flusher
/// advancing the durable watermark and pruning retained frames, and a
/// registrar seeding a new subscriber.
///
/// `buggy = true` splits the registrar's snapshot-retained /
/// register-slot into two steps, modelling seeding done *outside* the
/// registration critical section; the fixed variant is the single
/// `PubShared.inner` critical section `accept_loop` actually uses.
pub struct PubSeed {
    pub buggy: bool,
}

impl Model for PubSeed {
    type State = PubSeedState;

    fn init(&self) -> PubSeedState {
        PubSeedState {
            published: 0,
            retained: Vec::new(),
            durable: 0,
            delivered: Vec::new(),
            seed_from: 0,
            registered: false,
            pending_seed: None,
            registrar_pc: 0,
        }
    }

    fn threads(&self) -> usize {
        3 // 0 = publishing writer, 1 = flusher, 2 = registrar
    }

    fn step(&self, tid: usize, s: &mut PubSeedState) -> Step {
        match tid {
            // writer: on_batch under PubInner — retain the frame and push
            // it to every registered subscriber, atomically
            0 => {
                if s.published == PUB_SEQS {
                    return Step::Done;
                }
                s.published += 1;
                s.retained.push(s.published);
                if s.registered {
                    s.delivered.push(s.published);
                }
                if s.published == PUB_SEQS { Step::Done } else { Step::Progress }
            }
            // flusher: on_durable under PubInner — advance the watermark
            // one published seq at a time and prune retained frames ≤ it
            1 => {
                if s.durable == s.published {
                    return if s.published == PUB_SEQS { Step::Done } else { Step::Blocked };
                }
                s.durable += 1;
                let d = s.durable;
                s.retained.retain(|&q| q > d);
                Step::Progress
            }
            // registrar: seed + register
            _ => {
                if !self.buggy {
                    // fixed: ONE PubInner critical section — snapshot the
                    // retained frames, record the watermark, register
                    if s.registrar_pc == 0 {
                        s.delivered = s.retained.clone();
                        s.seed_from = s.durable;
                        s.registered = true;
                        s.registrar_pc = 1;
                    }
                    Step::Done
                } else {
                    match s.registrar_pc {
                        // buggy: snapshot under the lock…
                        0 => {
                            s.pending_seed = Some((s.retained.clone(), s.durable));
                            s.registrar_pc = 1;
                            Step::Progress
                        }
                        // …then register in a second critical section; any
                        // frame published in between is in neither the
                        // seed nor the slot
                        _ => {
                            let (seed, from) = s.pending_seed.take().unwrap();
                            s.delivered = seed;
                            s.seed_from = from;
                            s.registered = true;
                            Step::Done
                        }
                    }
                }
            }
        }
    }
}

/// Gapless-seed invariant: once registered, the subscriber's delivered
/// set covers every sequence in `(seed_from, published]` — its file
/// mirror is complete at `seed_from`, so that interval is exactly what
/// replay owes it.
pub fn pub_seed_invariant(s: &PubSeedState) -> Result<(), String> {
    if !s.registered {
        return Ok(());
    }
    for seq in (s.seed_from + 1)..=s.published {
        if !s.delivered.contains(&seq) {
            return Err(format!(
                "subscriber seeded from watermark {} is missing seq {seq} \
                 (published through {}): gapped seed",
                s.seed_from, s.published
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn checker() -> Checker {
        // 3 preemptions is the CHESS-style sweet spot; each test asserts
        // an execution floor so an accidentally trivial search can't pass
        Checker { max_preemptions: 3, max_executions: 2_000_000 }
    }

    #[test]
    fn commit_flush_barriers_protect_the_recoverable_epoch() {
        let stats = checker().explore(&CommitFlush { buggy: false }, commit_flush_invariant);
        // writer (10 phases) × flusher (6 ops) × fault at every point:
        // anything below this floor means the search wasn't real
        assert!(
            stats.executions >= 50,
            "suspiciously few interleavings explored: {stats:?}"
        );
        assert!(stats.max_interleaving_len >= 10);
    }

    #[test]
    fn commit_flush_unordered_flip_is_caught() {
        let (stats, violation) =
            checker().explore_collect(&CommitFlush { buggy: true }, commit_flush_invariant);
        let v = violation.unwrap_or_else(|| {
            panic!("flip-before-footer must violate the epoch floor; stats {stats:?}")
        });
        assert!(v.message.contains("torn footer"), "got: {}", v.message);
    }

    #[test]
    fn pin_retire_fixed_protocol_never_frees_pinned_extents() {
        let stats = checker().explore(&PinRetire { buggy: false }, pin_retire_invariant);
        assert!(
            stats.executions >= 10,
            "suspiciously few interleavings explored: {stats:?}"
        );
    }

    #[test]
    fn pin_retire_split_pin_epoch_race_is_caught() {
        // the exact race the PR fixes in pin_epoch: epoch load and pin
        // insert as two steps lets a commit free the extent in between
        let (stats, violation) =
            checker().explore_collect(&PinRetire { buggy: true }, pin_retire_invariant);
        let v = violation.unwrap_or_else(|| {
            panic!("split pin_epoch must allow freed-while-pinned; stats {stats:?}")
        });
        assert!(v.message.contains("freed while a pin"), "got: {}", v.message);
    }

    #[test]
    fn pub_seed_critical_section_is_gapless() {
        let stats = checker().explore(&PubSeed { buggy: false }, pub_seed_invariant);
        assert!(
            stats.executions >= 20,
            "suspiciously few interleavings explored: {stats:?}"
        );
    }

    #[test]
    fn pub_seed_split_registration_gap_is_caught() {
        let (stats, violation) =
            checker().explore_collect(&PubSeed { buggy: true }, pub_seed_invariant);
        let v = violation.unwrap_or_else(|| {
            panic!("snapshot/register split must gap the seed; stats {stats:?}")
        });
        assert!(v.message.contains("gapped seed"), "got: {}", v.message);
    }
}
