//! A bounded-interleaving model checker — a deliberately small loom.
//!
//! A protocol under test is expressed as a [`Model`]: a cloneable state
//! plus a fixed set of logical threads, each advanced one atomic step at
//! a time by [`Model::step`]. The [`Checker`] runs a depth-first search
//! over *every* scheduling decision (bounded by a preemption budget, the
//! standard trick from CHESS-style checkers: almost all real concurrency
//! bugs manifest within 2–3 preemptions), cloning the state at each
//! branch point. An invariant closure is evaluated on **every state of
//! every explored interleaving** — a property checked here holds on all
//! schedules within the bound, not one lucky `cargo test` run.
//!
//! What a step means is the model author's contract: everything inside
//! one `step` call is atomic (as if under one lock); anything that must
//! be preemptible must be split across steps with explicit per-thread
//! program counters. That makes models of *races* direct: model the racy
//! code as two steps, model the fixed code as one, and let the checker
//! find (or prove away) the interleaving that breaks the invariant.
//!
//! The checker is pure safe Rust with no real threads, no I/O and no
//! wall-clock — it runs unchanged under Miri, which the CI Miri leg
//! exploits.

/// Outcome of advancing one logical thread by one atomic step.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Step {
    /// The thread did work; it can be scheduled again.
    Progress,
    /// The thread cannot run right now (e.g. a modelled condvar wait or
    /// an empty queue). A `Blocked` step MUST NOT mutate the state — the
    /// scheduler probes runnability by trial-stepping a clone.
    Blocked,
    /// The thread has finished; it will never be scheduled again.
    Done,
}

/// A small protocol model: cloneable state + `threads` logical threads
/// advanced by [`Model::step`].
pub trait Model {
    /// Snapshot of the whole modelled world. Cloned at every branch
    /// point of the DFS, so keep it small (a few ints/vecs).
    type State: Clone;

    /// The initial state.
    fn init(&self) -> Self::State;

    /// Number of logical threads; thread ids are `0..threads`.
    fn threads(&self) -> usize;

    /// Advance thread `tid` by one atomic step. Must be deterministic in
    /// `state`, and must not mutate `state` when returning
    /// [`Step::Blocked`].
    fn step(&self, tid: usize, state: &mut Self::State) -> Step;
}

/// Exploration statistics, returned so tests can assert the search was
/// genuinely exhaustive (an accidental one-interleaving walk would pass
/// any invariant).
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    /// Complete interleavings explored to a terminal (all threads Done).
    pub executions: usize,
    /// Total states visited (steps taken) across all interleavings.
    pub states_visited: usize,
    /// Schedule branches pruned by the preemption bound.
    pub preemption_pruned: usize,
    /// Longest interleaving, in steps.
    pub max_interleaving_len: usize,
}

/// A violation found by [`Checker::explore_collect`]: which invariant
/// message fired, and the schedule (thread-id sequence) that reached it.
#[derive(Clone, Debug)]
pub struct Violation {
    pub message: String,
    pub schedule: Vec<usize>,
}

/// Bounded-DFS explorer over a [`Model`]'s interleavings.
pub struct Checker {
    /// Maximum preemptions per interleaving. A preemption is scheduling
    /// away from the last-run thread while it is still runnable;
    /// running on after a block/finish is free. 2–3 suffices for almost
    /// all real bugs and keeps the search exhaustive-yet-tractable.
    pub max_preemptions: usize,
    /// Hard cap on explored terminal executions — a runaway-model
    /// backstop (panics if exceeded), orders of magnitude above any
    /// intended model here.
    pub max_executions: usize,
}

impl Default for Checker {
    fn default() -> Checker {
        Checker { max_preemptions: 3, max_executions: 2_000_000 }
    }
}

struct Search<'a, M: Model, F> {
    model: &'a M,
    invariant: &'a F,
    max_preemptions: usize,
    max_executions: usize,
    stats: Stats,
    schedule: Vec<usize>,
    first_violation: Option<Violation>,
    stop_on_violation: bool,
}

impl<M, F> Search<'_, M, F>
where
    M: Model,
    F: Fn(&M::State) -> Result<(), String>,
{
    /// DFS from `state` with `done` flags per thread, `last` = thread
    /// that ran the previous step (None at the root), `preemptions`
    /// spent so far.
    fn dfs(
        &mut self,
        state: &M::State,
        done: &[bool],
        last: Option<usize>,
        preemptions: usize,
    ) {
        if self.first_violation.is_some() && self.stop_on_violation {
            return;
        }

        // Probe every not-done thread on a clone: which can make progress
        // from this state? (Blocked steps are contractually side-effect
        // free, so the probe clone for a runnable thread doubles as the
        // branch state below.)
        let n = self.model.threads();
        let mut runnable: Vec<(usize, M::State, Step)> = Vec::new();
        for tid in 0..n {
            if done[tid] {
                continue;
            }
            let mut branch = state.clone();
            match self.model.step(tid, &mut branch) {
                Step::Blocked => {}
                s => runnable.push((tid, branch, s)),
            }
        }

        if runnable.is_empty() {
            if done.iter().all(|&d| d) {
                // terminal: one complete interleaving
                self.stats.executions += 1;
                assert!(
                    self.stats.executions <= self.max_executions,
                    "model checker execution cap exceeded ({}) — model too large \
                     or a thread never terminates",
                    self.max_executions
                );
                self.stats.max_interleaving_len =
                    self.stats.max_interleaving_len.max(self.schedule.len());
            } else {
                // live threads, none runnable: a modelled deadlock
                let stuck: Vec<usize> =
                    (0..n).filter(|&t| !done[t]).collect();
                let v = Violation {
                    message: format!(
                        "deadlock: threads {stuck:?} blocked with no runnable peer \
                         after schedule {:?}",
                        self.schedule
                    ),
                    schedule: self.schedule.clone(),
                };
                if self.stop_on_violation {
                    self.first_violation.get_or_insert(v);
                } else {
                    panic!("{}", v.message);
                }
            }
            return;
        }

        let last_still_runnable =
            last.is_some_and(|l| runnable.iter().any(|&(t, _, _)| t == l));

        for (tid, branch, step) in runnable {
            // preemption accounting: switching away from a thread that
            // could have continued costs budget
            let preempt = last_still_runnable && last != Some(tid);
            let budget = if preempt { preemptions + 1 } else { preemptions };
            if budget > self.max_preemptions {
                self.stats.preemption_pruned += 1;
                continue;
            }

            self.stats.states_visited += 1;
            self.schedule.push(tid);
            if let Err(msg) = (self.invariant)(&branch) {
                let v = Violation {
                    message: format!("invariant violated: {msg} (schedule {:?})", self.schedule),
                    schedule: self.schedule.clone(),
                };
                if self.stop_on_violation {
                    self.first_violation.get_or_insert(v);
                    self.schedule.pop();
                    return;
                }
                panic!("{}", v.message);
            }
            let mut next_done = done.to_vec();
            if step == Step::Done {
                next_done[tid] = true;
            }
            self.dfs(&branch, &next_done, Some(tid), budget);
            self.schedule.pop();
        }
    }
}

impl Checker {
    /// Explore every interleaving within the preemption bound, asserting
    /// `invariant` on every visited state. Panics (failing the enclosing
    /// test) on the first invariant violation or modelled deadlock;
    /// returns exploration [`Stats`] otherwise.
    pub fn explore<M, F>(&self, model: &M, invariant: F) -> Stats
    where
        M: Model,
        F: Fn(&M::State) -> Result<(), String>,
    {
        let mut search = Search {
            model,
            invariant: &invariant,
            max_preemptions: self.max_preemptions,
            max_executions: self.max_executions,
            stats: Stats::default(),
            schedule: Vec::new(),
            first_violation: None,
            stop_on_violation: false,
        };
        let init = model.init();
        if let Err(msg) = invariant(&init) {
            panic!("invariant violated in initial state: {msg}");
        }
        search.dfs(&init, &vec![false; model.threads()], None, 0);
        assert!(
            search.stats.executions > 0,
            "model explored zero complete interleavings — every schedule deadlocked?"
        );
        search.stats
    }

    /// Like [`Checker::explore`] but *collects* the first violation
    /// instead of panicking — for tests that assert a deliberately buggy
    /// model variant IS caught (the checker's own regression tests).
    pub fn explore_collect<M, F>(&self, model: &M, invariant: F) -> (Stats, Option<Violation>)
    where
        M: Model,
        F: Fn(&M::State) -> Result<(), String>,
    {
        let mut search = Search {
            model,
            invariant: &invariant,
            max_preemptions: self.max_preemptions,
            max_executions: self.max_executions,
            stats: Stats::default(),
            schedule: Vec::new(),
            first_violation: None,
            stop_on_violation: true,
        };
        let init = model.init();
        if let Err(msg) = invariant(&init) {
            return (
                Stats::default(),
                Some(Violation { message: format!("initial state: {msg}"), schedule: vec![] }),
            );
        }
        search.dfs(&init, &vec![false; model.threads()], None, 0);
        (search.stats, search.first_violation)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads each increment a shared counter `per_thread` times.
    struct Counter {
        per_thread: u32,
    }

    #[derive(Clone)]
    struct CounterState {
        value: u32,
        pc: [u32; 2],
    }

    impl Model for Counter {
        type State = CounterState;
        fn init(&self) -> CounterState {
            CounterState { value: 0, pc: [0, 0] }
        }
        fn threads(&self) -> usize {
            2
        }
        fn step(&self, tid: usize, s: &mut CounterState) -> Step {
            s.value += 1;
            s.pc[tid] += 1;
            if s.pc[tid] == self.per_thread { Step::Done } else { Step::Progress }
        }
    }

    #[test]
    fn counter_explores_all_interleavings() {
        // 2 threads × 2 atomic steps, unbounded preemptions: the
        // interleavings of AABB are C(4,2) = 6
        let checker = Checker { max_preemptions: usize::MAX, max_executions: 1_000 };
        let stats = checker.explore(&Counter { per_thread: 2 }, |s| {
            if s.value == s.pc[0] + s.pc[1] {
                Ok(())
            } else {
                Err(format!("value {} != pc sum {}", s.value, s.pc[0] + s.pc[1]))
            }
        });
        assert_eq!(stats.executions, 6);
        assert_eq!(stats.max_interleaving_len, 4);
    }

    #[test]
    fn preemption_bound_prunes_schedules() {
        let all = Checker { max_preemptions: usize::MAX, max_executions: 100_000 }
            .explore(&Counter { per_thread: 3 }, |_| Ok(()));
        let bounded = Checker { max_preemptions: 1, max_executions: 100_000 }
            .explore(&Counter { per_thread: 3 }, |_| Ok(()));
        assert!(bounded.executions < all.executions);
        assert!(bounded.preemption_pruned > 0);
        // bound 1 over 2 threads: run-to-block schedules plus one switch
        // back and forth; at least the two run-to-completion orders exist
        assert!(bounded.executions >= 2);
    }

    /// Classic AB/BA deadlock, modelled: thread 0 takes lock A then B,
    /// thread 1 takes B then A; a taken lock blocks the other thread.
    struct AbBa;

    #[derive(Clone)]
    struct AbBaState {
        lock_a: Option<usize>,
        lock_b: Option<usize>,
        pc: [u8; 2],
    }

    impl Model for AbBa {
        type State = AbBaState;
        fn init(&self) -> AbBaState {
            AbBaState { lock_a: None, lock_b: None, pc: [0, 0] }
        }
        fn threads(&self) -> usize {
            2
        }
        fn step(&self, tid: usize, s: &mut AbBaState) -> Step {
            // thread 0: A then B; thread 1: B then A; then release both
            let (first, second) = if tid == 0 {
                (&mut s.lock_a, &mut s.lock_b)
            } else {
                (&mut s.lock_b, &mut s.lock_a)
            };
            match s.pc[tid] {
                0 => {
                    if first.is_some() {
                        return Step::Blocked;
                    }
                    *first = Some(tid);
                }
                1 => {
                    if second.is_some() {
                        return Step::Blocked;
                    }
                    *second = Some(tid);
                }
                2 => {
                    *first = None;
                    *second = None;
                    s.pc[tid] += 1;
                    return Step::Done;
                }
                _ => unreachable!(),
            }
            s.pc[tid] += 1;
            Step::Progress
        }
    }

    #[test]
    fn ab_ba_deadlock_is_detected() {
        let checker = Checker { max_preemptions: usize::MAX, max_executions: 1_000 };
        let (stats, violation) = checker.explore_collect(&AbBa, |_| Ok(()));
        let v = violation.expect("AB/BA must deadlock on some schedule");
        assert!(v.message.contains("deadlock"), "got: {}", v.message);
        // the deadlocking schedule is the alternation: 0 takes A, 1 takes B
        assert!(stats.states_visited > 0);
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn explore_panics_on_deadlock() {
        Checker { max_preemptions: usize::MAX, max_executions: 1_000 }
            .explore(&AbBa, |_| Ok(()));
    }

    /// Blocked steps must not mutate: the checker relies on probing
    /// runnability with trial steps on clones that are then reused.
    struct HandShake;

    #[derive(Clone)]
    struct HandShakeState {
        token: bool,
        pc: [u8; 2],
    }

    impl Model for HandShake {
        type State = HandShakeState;
        fn init(&self) -> HandShakeState {
            HandShakeState { token: false, pc: [0, 0] }
        }
        fn threads(&self) -> usize {
            2
        }
        fn step(&self, tid: usize, s: &mut HandShakeState) -> Step {
            match tid {
                0 => {
                    s.token = true;
                    s.pc[0] += 1;
                    Step::Done
                }
                _ => {
                    if !s.token {
                        return Step::Blocked; // waits for thread 0's token
                    }
                    s.pc[1] += 1;
                    Step::Done
                }
            }
        }
    }

    #[test]
    fn blocked_threads_wake_when_enabled() {
        let stats = Checker::default().explore(&HandShake, |_| Ok(()));
        // exactly one schedule: 1 is blocked until 0 runs
        assert_eq!(stats.executions, 1);
        assert_eq!(stats.max_interleaving_len, 2);
    }

    #[test]
    fn invariant_violation_is_collected_with_schedule() {
        let checker = Checker { max_preemptions: usize::MAX, max_executions: 1_000 };
        let (_, violation) = checker.explore_collect(&Counter { per_thread: 1 }, |s| {
            if s.value > 1 { Err("value exceeded 1".into()) } else { Ok(()) }
        });
        let v = violation.expect("2 increments must exceed 1");
        assert_eq!(v.schedule.len(), 2, "violation after the second step");
    }
}
