//! The **d-grid batcher**: gathers a set of grids' fields into contiguous
//! batch buffers for the compute backend and scatters results back.
//!
//! This is the L3 half of the batching story (DESIGN.md §Hardware-
//! Adaptation): the AOT artifacts are shape-specialised to a batch of
//! blocks, and this module feeds them — amortising PJRT dispatch overhead
//! across many d-grids exactly as the paper amortises MPI messages.

use crate::exchange::Gen;
use crate::tree::dgrid::{DGrid, PADDED_LEN};
use crate::util::{parallel_for, SendPtr};
use crate::DGRID_CELLS;

/// Pack the halo-padded field `var`/`gen` of the listed grids into one
/// contiguous `(B, (N+2)³)` buffer.
pub fn pack_halo(grids: &[DGrid], idxs: &[u32], gen: Gen, var: usize, out: &mut Vec<f32>) {
    out.resize(idxs.len() * PADDED_LEN, 0.0);
    let ptr = SendPtr::new(&mut out[..]);
    parallel_for(idxs.len(), |i| {
        // SAFETY: task i owns out rows [i*PADDED_LEN, (i+1)*PADDED_LEN).
        let dst = unsafe { ptr.slice(i * PADDED_LEN, PADDED_LEN) };
        dst.copy_from_slice(gen.of(&grids[idxs[i] as usize]).var(var));
    });
}

/// Scatter a `(B, N³)` interior batch back into the grids' field `var`.
pub fn scatter_interior(
    grids: &mut [DGrid],
    idxs: &[u32],
    gen: Gen,
    var: usize,
    data: &[f32],
) {
    assert_eq!(data.len(), idxs.len() * DGRID_CELLS);
    let ptr = SendPtr::new(grids);
    parallel_for(idxs.len(), |i| {
        // SAFETY: distinct idxs ⇒ disjoint grids, one task per index (the
        // debug claims registry rejects a duplicated index).
        let g = unsafe { &mut ptr.slice(idxs[i] as usize, 1)[0] };
        gen.of_mut(g)
            .set_interior(var, &data[i * DGRID_CELLS..(i + 1) * DGRID_CELLS]);
    });
}

/// Gather the interiors of `var`/`gen` into a `(B, N³)` buffer.
pub fn pack_interior(grids: &[DGrid], idxs: &[u32], gen: Gen, var: usize, out: &mut Vec<f32>) {
    out.resize(idxs.len() * DGRID_CELLS, 0.0);
    let ptr = SendPtr::new(&mut out[..]);
    parallel_for(idxs.len(), |i| {
        // SAFETY: task i owns out rows [i*DGRID_CELLS, (i+1)*DGRID_CELLS).
        let dst = unsafe { ptr.slice(i * DGRID_CELLS, DGRID_CELLS) };
        gen.of(&grids[idxs[i] as usize]).extract_interior(var, dst);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::dgrid::pidx;
    use crate::tree::uid::{LocCode, Uid};
    use crate::var;

    fn grids(n: usize) -> Vec<DGrid> {
        (0..n)
            .map(|i| {
                let mut g = DGrid::new(Uid::new(0, i as u32, LocCode::ROOT));
                let data = vec![i as f32; DGRID_CELLS];
                g.cur.set_interior(var::P, &data);
                g.cur.var_mut(var::P)[pidx(0, 0, 0)] = 99.0; // halo marker
                g
            })
            .collect()
    }

    #[test]
    fn pack_halo_includes_ghosts() {
        let gs = grids(3);
        let mut buf = Vec::new();
        pack_halo(&gs, &[2, 0], Gen::Cur, var::P, &mut buf);
        assert_eq!(buf.len(), 2 * PADDED_LEN);
        assert_eq!(buf[pidx(0, 0, 0)], 99.0); // grid 2's halo marker
        assert_eq!(buf[pidx(5, 5, 5)], 2.0); // grid 2 interior
        assert_eq!(buf[PADDED_LEN + pidx(5, 5, 5)], 0.0); // grid 0 interior
    }

    #[test]
    fn scatter_roundtrip() {
        let mut gs = grids(2);
        let data: Vec<f32> = (0..2 * DGRID_CELLS).map(|x| x as f32).collect();
        scatter_interior(&mut gs, &[1, 0], Gen::Temp, var::T, &data);
        let mut out = Vec::new();
        pack_interior(&gs, &[1, 0], Gen::Temp, var::T, &mut out);
        assert_eq!(out, data);
        // grid order respected: grid 1 got the first block
        let mut one = vec![0.0f32; DGRID_CELLS];
        gs[1].temp.extract_interior(var::T, &mut one);
        assert_eq!(one[0], 0.0);
        assert_eq!(one[DGRID_CELLS - 1], (DGRID_CELLS - 1) as f32);
    }
}
