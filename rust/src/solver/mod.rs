//! The **multigrid-like pressure-Poisson solver** (paper §2.2, after
//! Brandt [14]).
//!
//! "Multigrid-like, because it utilises the above communication schema —
//! precisely the bottom-up and top-down update steps — as restriction and
//! prolongation operators for setting up a cell-centred multigrid method."
//!
//! Exactly that: the V-cycle below walks the space-tree's depth levels,
//! smoothing with the AOT Jacobi kernel at every level (the d-grid shape is
//! 16³ at *all* depths, so one artifact serves the whole hierarchy; only
//! the spacing `h` in the params vector changes), restricting residuals
//! bottom-up into the parents' d-grids and prolongating corrections
//! top-down — the same data paths as the ghost-layer communication phase.
//!
//! The right-hand side is expected in `temp.P` of the finest-level grids;
//! the solution accumulates in `cur.P`.
//!
//! For adaptively refined trees (leaves at several depths), the solver
//! falls back to plain smoothing sweeps over the leaves with the full
//! three-phase exchange between sweeps — the paper itself reports
//! "convergence instabilities for certain scenarios (in case of adaptive
//! refinement)" for the V-cycle and counters them with extra smoothing; we
//! take the robust route.

pub mod batch;

use crate::exchange::{self, Gen};
use crate::nbs::{Face, NeighbourhoodServer, Neighbour, ALL_FACES};
use crate::physics::bc::{apply_face_bc, DomainBc};
use crate::physics::{ComputeBackend, Params};
use crate::tree::dgrid::{pidx, DGrid};
use crate::{var, DGRID_CELLS, DGRID_N};

/// Solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct SolverConfig {
    /// Pre-smoothing sweeps per level.
    pub nu1: usize,
    /// Post-smoothing sweeps per level.
    pub nu2: usize,
    /// Extra sweeps on the coarsest grid.
    pub coarse_sweeps: usize,
    /// Maximum V-cycles (or leaf-sweep rounds × 10 in fallback mode).
    pub max_cycles: usize,
    /// Stop when ‖r‖₂ / ‖r₀‖₂ falls below this.
    pub rtol: f32,
    /// Double smoothing on coarser levels (the paper's stabilisation).
    pub boost_coarse: bool,
}

impl SolverConfig {
    /// The per-time-step configuration the coordinator uses: the projection
    /// only needs the divergence driven well below the advection scale, and
    /// the warm-started V-cycle then converges in a few cycles (perf pass).
    pub fn per_step() -> SolverConfig {
        SolverConfig {
            rtol: 2e-3,
            max_cycles: 10,
            ..SolverConfig::default()
        }
    }
}

impl Default for SolverConfig {
    fn default() -> SolverConfig {
        SolverConfig {
            nu1: 3,
            nu2: 3,
            coarse_sweeps: 40,
            max_cycles: 30,
            rtol: 1e-4,
            boost_coarse: true,
        }
    }
}

/// Outcome of one pressure solve.
#[derive(Clone, Copy, Debug)]
pub struct SolveStats {
    pub cycles: usize,
    pub initial_residual: f32,
    pub final_residual: f32,
    pub converged: bool,
    /// Total smoothing sweeps dispatched (per level counted once).
    pub sweeps: usize,
    pub seconds: f64,
}

/// Ghost exchange for one variable among the grids **at one depth**,
/// handling all four neighbour kinds (same level, physical boundary,
/// coarser neighbour by injection, finer neighbour by face averaging).
/// This is the level-wise analogue of the three-phase schema used inside
/// the V-cycle.
pub fn level_exchange(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    depth: u32,
    gen: Gen,
    v: usize,
    bc: &DomainBc,
) {
    const N: usize = DGRID_N;
    // Parallel across receiving grids (perf pass, EXPERIMENTS §Perf): each
    // task writes only its own grid's ghost layer and reads only
    // neighbours' *interiors* — disjoint regions, expressed via SendPtr.
    let idxs = nbs.tree.nodes_at_depth(depth);
    // aliased: `me` is task-exclusive &mut, peers are shared reads of
    // interiors no task writes this pass — overlap is the contract here
    let gptr = crate::util::SendPtr::new_aliased(grids);
    crate::util::parallel_for(idxs.len(), |task| {
        let idx = idxs[task];
        let mut buf = [0.0f32; N * N];
        let mut src = [0.0f32; N * N];
        // SAFETY: task-exclusive mutable access to grid `idx`; shared reads
        // of other grids touch only cells no task writes in this pass.
        let me = unsafe { &mut gptr.slice(idx as usize, 1)[0] };
        let peer = |j: u32| -> &DGrid { unsafe { &gptr.slice(j as usize, 1)[0] } };
        for face in ALL_FACES {
            match nbs.neighbour(idx, face) {
                Neighbour::Boundary => {
                    apply_single_var_bc(gen.of_mut(me), face, v, bc.face(face));
                }
                Neighbour::Same { idx: nb } => {
                    exchange::read_face_layer(gen.of(peer(nb)), v, face.opposite(), &mut buf);
                    exchange::write_ghost_layer(gen.of_mut(me), v, face, &buf);
                }
                Neighbour::Coarser { idx: nb } => {
                    let (a_axis, b_axis) = exchange::tangential(face);
                    let node = nbs.tree.node(idx);
                    let (ci, cj, ck) = node.loc.coords();
                    let coords = [ci as usize, cj as usize, ck as usize];
                    let off_a = (coords[a_axis] % 2) * (N / 2);
                    let off_b = (coords[b_axis] % 2) * (N / 2);
                    exchange::read_face_layer(gen.of(peer(nb)), v, face.opposite(), &mut src);
                    for a in 0..N {
                        for b in 0..N {
                            buf[a * N + b] = src[(off_a + a / 2) * N + (off_b + b / 2)];
                        }
                    }
                    exchange::write_ghost_layer(gen.of_mut(me), v, face, &buf);
                }
                Neighbour::Finer { idx: kids } => {
                    let (a_axis, b_axis) = exchange::tangential(face);
                    for &ch in &kids {
                        let chn = nbs.tree.node(ch);
                        let (ki, kj, kk) = chn.loc.coords();
                        let kcoords = [ki as usize, kj as usize, kk as usize];
                        let off_a = (kcoords[a_axis] % 2) * (N / 2);
                        let off_b = (kcoords[b_axis] % 2) * (N / 2);
                        exchange::read_face_layer(gen.of(peer(ch)), v, face.opposite(), &mut src);
                        for a in 0..N / 2 {
                            for b in 0..N / 2 {
                                buf[(off_a + a) * N + off_b + b] = 0.25
                                    * (src[(2 * a) * N + 2 * b]
                                        + src[(2 * a) * N + 2 * b + 1]
                                        + src[(2 * a + 1) * N + 2 * b]
                                        + src[(2 * a + 1) * N + 2 * b + 1]);
                            }
                        }
                    }
                    exchange::write_ghost_layer(gen.of_mut(me), v, face, &buf);
                }
            }
        }
    });
}

/// Apply one variable's boundary condition on one face.
fn apply_single_var_bc(
    fs: &mut crate::tree::dgrid::FieldSet,
    face: Face,
    v: usize,
    bc: &crate::physics::bc::FaceBc,
) {
    let mut only = crate::physics::bc::FaceBc {
        per_var: [crate::physics::bc::VarBc::Neumann; crate::NVAR],
    };
    only.per_var[v] = bc.per_var[v];
    // Neumann for the others is a harmless overwrite of ghost values that
    // the current kernel call does not read; still, keep it to v only by
    // filling the other slots with their own current spec:
    apply_face_bc(fs, face, &only);
}

/// `sweeps` Jacobi sweeps over the nodes at `depth` (rhs in `temp.P`,
/// solution in `cur.P`), exchanging ghosts before every sweep.
#[allow(clippy::too_many_arguments)]
fn smooth_level(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    idxs: &[u32],
    depth: u32,
    par: &Params,
    backend: &dyn ComputeBackend,
    bc: &DomainBc,
    sweeps: usize,
    scratch: &mut Scratch,
) {
    for _ in 0..sweeps {
        level_exchange(nbs, grids, depth, Gen::Cur, var::P, bc);
        batch::pack_halo(grids, idxs, Gen::Cur, var::P, &mut scratch.p);
        batch::pack_interior(grids, idxs, Gen::Temp, var::P, &mut scratch.rhs);
        scratch.out.resize(idxs.len() * DGRID_CELLS, 0.0);
        backend.jacobi(idxs.len(), &scratch.p, &scratch.rhs, par, &mut scratch.out);
        batch::scatter_interior(grids, idxs, Gen::Cur, var::P, &scratch.out);
    }
}

/// Residual at `depth` (after a ghost refresh): r → `temp.T`, returns Σr².
#[allow(clippy::too_many_arguments)]
fn residual_level(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    idxs: &[u32],
    depth: u32,
    par: &Params,
    backend: &dyn ComputeBackend,
    bc: &DomainBc,
    scratch: &mut Scratch,
) -> f32 {
    level_exchange(nbs, grids, depth, Gen::Cur, var::P, bc);
    batch::pack_halo(grids, idxs, Gen::Cur, var::P, &mut scratch.p);
    batch::pack_interior(grids, idxs, Gen::Temp, var::P, &mut scratch.rhs);
    scratch.out.resize(idxs.len() * DGRID_CELLS, 0.0);
    scratch.ssq.resize(idxs.len(), 0.0);
    backend.residual(
        idxs.len(),
        &scratch.p,
        &scratch.rhs,
        par,
        &mut scratch.out,
        &mut scratch.ssq,
    );
    batch::scatter_interior(grids, idxs, Gen::Temp, var::T, &scratch.out);
    scratch.ssq.iter().sum()
}

/// Restrict the residual (`temp.T` of the children at `depth`) into the
/// parents' rhs (`temp.P`), and zero the parents' `cur.P` correction.
fn restrict_residual(nbs: &NeighbourhoodServer, grids: &mut [DGrid], depth: u32) {
    const N: usize = DGRID_N;
    let m = N / 2;
    for pidx_ in nbs.tree.nodes_at_depth(depth - 1) {
        let node = nbs.tree.node(pidx_);
        if node.is_leaf() {
            continue;
        }
        let children = node.children.clone();
        // zero correction
        for x in grids[pidx_ as usize].cur.var_mut(var::P).iter_mut() {
            *x = 0.0;
        }
        let mut interior = vec![0.0f32; DGRID_CELLS];
        let mut block = vec![0.0f32; m * m * m];
        for &ch in &children {
            let oct = nbs.tree.node(ch).loc.octant();
            let (oi, oj, ok) = (
                ((oct >> 2) & 1) as usize,
                ((oct >> 1) & 1) as usize,
                (oct & 1) as usize,
            );
            grids[ch as usize]
                .temp
                .extract_interior(var::T, &mut interior);
            crate::physics::restrict_block(N, &interior, &mut block);
            let f = grids[pidx_ as usize].temp.var_mut(var::P);
            for i in 0..m {
                for j in 0..m {
                    for k in 0..m {
                        f[pidx(oi * m + i + 1, oj * m + j + 1, ok * m + k + 1)] =
                            block[(i * m + j) * m + k];
                    }
                }
            }
        }
    }
}

/// Prolongate the coarse correction (`cur.P` at `depth-1`) into the
/// children's `cur.P` (piecewise-constant injection, additive).
fn prolong_correction(nbs: &NeighbourhoodServer, grids: &mut [DGrid], depth: u32) {
    const N: usize = DGRID_N;
    let m = N / 2;
    for pidx_ in nbs.tree.nodes_at_depth(depth - 1) {
        let node = nbs.tree.node(pidx_);
        if node.is_leaf() {
            continue;
        }
        let children = node.children.clone();
        let mut octant = vec![0.0f32; m * m * m];
        for &ch in &children {
            let oct = nbs.tree.node(ch).loc.octant();
            let (oi, oj, ok) = (
                ((oct >> 2) & 1) as usize,
                ((oct >> 1) & 1) as usize,
                (oct & 1) as usize,
            );
            {
                let f = grids[pidx_ as usize].cur.var(var::P);
                for i in 0..m {
                    for j in 0..m {
                        for k in 0..m {
                            octant[(i * m + j) * m + k] =
                                f[pidx(oi * m + i + 1, oj * m + j + 1, ok * m + k + 1)];
                        }
                    }
                }
            }
            let cf = grids[ch as usize].cur.var_mut(var::P);
            for i in 0..m {
                for j in 0..m {
                    for k in 0..m {
                        let c = octant[(i * m + j) * m + k];
                        for (di, dj, dk) in [
                            (0, 0, 0),
                            (0, 0, 1),
                            (0, 1, 0),
                            (0, 1, 1),
                            (1, 0, 0),
                            (1, 0, 1),
                            (1, 1, 0),
                            (1, 1, 1),
                        ] {
                            cf[pidx(2 * i + di + 1, 2 * j + dj + 1, 2 * k + dk + 1)] += c;
                        }
                    }
                }
            }
        }
    }
}

#[derive(Default)]
struct Scratch {
    p: Vec<f32>,
    rhs: Vec<f32>,
    out: Vec<f32>,
    ssq: Vec<f32>,
}

/// Solve ∇²p = rhs (rhs in `temp.P` of the finest grids, solution in
/// `cur.P`). Chooses the V-cycle for uniformly refined trees and leaf
/// smoothing otherwise.
pub fn solve_pressure(
    nbs: &NeighbourhoodServer,
    grids: &mut [DGrid],
    bc: &DomainBc,
    par: &Params,
    backend: &dyn ComputeBackend,
    cfg: &SolverConfig,
) -> SolveStats {
    let t0 = std::time::Instant::now();
    let max_d = nbs.tree.max_depth();
    let uniform = nbs
        .tree
        .nodes
        .iter()
        .all(|n| !n.is_leaf() || n.depth() == max_d);
    let mut scratch = Scratch::default();
    let finest: Vec<u32> = nbs.tree.nodes_at_depth(max_d);
    // damped Jacobi (ω = 6/7): the undamped sweep does not smooth the
    // highest-frequency modes of the 3-D 7-point Laplacian (μ = −1), which
    // stalls the coarse-grid correction entirely.
    let par_at = |d: u32| {
        let mut p = par.at_h(nbs.tree.h_at_depth(d) as f32);
        p.omega = 6.0 / 7.0;
        p
    };

    let mut stats = SolveStats {
        cycles: 0,
        initial_residual: 0.0,
        final_residual: 0.0,
        converged: false,
        sweeps: 0,
        seconds: 0.0,
    };
    let r0 = residual_level(
        nbs,
        grids,
        &finest,
        max_d,
        &par_at(max_d),
        backend,
        bc,
        &mut scratch,
    )
    .sqrt();
    stats.initial_residual = r0;
    let target = (r0 * cfg.rtol).max(1e-12);
    let mut r = r0;

    if uniform && max_d > 0 {
        // ----- V-cycles over the tree hierarchy --------------------------
        while stats.cycles < cfg.max_cycles && r > target {
            // fine → coarse
            for d in (1..=max_d).rev() {
                let idxs = nbs.tree.nodes_at_depth(d);
                let boost = if cfg.boost_coarse {
                    1 << (max_d - d).min(3)
                } else {
                    1
                };
                smooth_level(
                    nbs,
                    grids,
                    &idxs,
                    d,
                    &par_at(d),
                    backend,
                    bc,
                    cfg.nu1 * boost,
                    &mut scratch,
                );
                stats.sweeps += cfg.nu1 * boost;
                residual_level(nbs, grids, &idxs, d, &par_at(d), backend, bc, &mut scratch);
                restrict_residual(nbs, grids, d);
            }
            // coarsest
            let root = nbs.tree.nodes_at_depth(0);
            smooth_level(
                nbs,
                grids,
                &root,
                0,
                &par_at(0),
                backend,
                bc,
                cfg.coarse_sweeps,
                &mut scratch,
            );
            stats.sweeps += cfg.coarse_sweeps;
            // coarse → fine
            for d in 1..=max_d {
                prolong_correction(nbs, grids, d);
                let idxs = nbs.tree.nodes_at_depth(d);
                let boost = if cfg.boost_coarse {
                    1 << (max_d - d).min(3)
                } else {
                    1
                };
                smooth_level(
                    nbs,
                    grids,
                    &idxs,
                    d,
                    &par_at(d),
                    backend,
                    bc,
                    cfg.nu2 * boost,
                    &mut scratch,
                );
                stats.sweeps += cfg.nu2 * boost;
            }
            stats.cycles += 1;
            r = residual_level(
                nbs,
                grids,
                &finest,
                max_d,
                &par_at(max_d),
                backend,
                bc,
                &mut scratch,
            )
            .sqrt();
        }
    } else {
        // ----- fallback: smoothing on leaves, grouped per depth ----------
        let depths: Vec<u32> = {
            let mut ds: Vec<u32> = nbs
                .tree
                .nodes
                .iter()
                .filter(|n| n.is_leaf())
                .map(|n| n.depth())
                .collect();
            ds.sort_unstable();
            ds.dedup();
            ds
        };
        let leaf_idxs: Vec<(u32, Vec<u32>)> = depths
            .iter()
            .map(|&d| {
                (
                    d,
                    nbs.tree
                        .nodes_at_depth(d)
                        .into_iter()
                        .filter(|&i| nbs.tree.node(i).is_leaf())
                        .collect(),
                )
            })
            .collect();
        let rounds = cfg.max_cycles * 10;
        while stats.cycles < rounds && r > target {
            for (d, idxs) in &leaf_idxs {
                smooth_level(
                    nbs,
                    grids,
                    idxs,
                    *d,
                    &par_at(*d),
                    backend,
                    bc,
                    cfg.nu1,
                    &mut scratch,
                );
                stats.sweeps += cfg.nu1;
            }
            stats.cycles += 1;
            if stats.cycles % 10 == 0 || stats.cycles == rounds {
                r = residual_level(
                    nbs,
                    grids,
                    &finest,
                    max_d,
                    &par_at(max_d),
                    backend,
                    bc,
                    &mut scratch,
                )
                .sqrt();
            }
        }
    }
    stats.final_residual = r;
    stats.converged = r <= target;
    stats.seconds = t0.elapsed().as_secs_f64();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::physics::RustBackend;
    use crate::tree::sfc;
    use crate::tree::{BBox, SpaceTree};
    use crate::util::rng::Rng;

    fn setup(depth: u32) -> (NeighbourhoodServer, Vec<DGrid>) {
        let mut t = SpaceTree::full(BBox::unit(), depth);
        sfc::partition(&mut t, 4);
        let grids: Vec<DGrid> = t.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        (NeighbourhoodServer::new(t), grids)
    }

    fn params() -> Params {
        Params::isothermal(0.01, 1.0, 0.0)
    }

    /// Put a zero-mean random rhs into temp.P of the finest level.
    fn random_rhs(nbs: &NeighbourhoodServer, grids: &mut [DGrid], seed: u64) {
        let max_d = nbs.tree.max_depth();
        let mut rng = Rng::new(seed);
        let idxs = nbs.tree.nodes_at_depth(max_d);
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut fields = Vec::new();
        for &i in &idxs {
            let mut f = vec![0.0f32; DGRID_CELLS];
            rng.fill_f32(&mut f, -1.0, 1.0);
            total += f.iter().map(|&x| x as f64).sum::<f64>();
            count += f.len();
            fields.push((i, f));
        }
        let mean = (total / count as f64) as f32;
        for (i, mut f) in fields {
            for x in f.iter_mut() {
                *x -= mean;
            }
            grids[i as usize].temp.set_interior(var::P, &f);
        }
    }

    #[test]
    fn vcycle_reduces_residual_depth1() {
        let (nbs, mut grids) = setup(1);
        random_rhs(&nbs, &mut grids, 3);
        let cfg = SolverConfig {
            max_cycles: 5,
            rtol: 1e-5,
            ..SolverConfig::default()
        };
        let stats = solve_pressure(
            &nbs,
            &mut grids,
            &DomainBc::all_walls(),
            &params(),
            &RustBackend,
            &cfg,
        );
        assert!(
            stats.final_residual < 0.05 * stats.initial_residual,
            "{stats:?}"
        );
    }

    #[test]
    fn vcycle_converges_depth2() {
        let (nbs, mut grids) = setup(2);
        random_rhs(&nbs, &mut grids, 5);
        let cfg = SolverConfig {
            max_cycles: 12,
            rtol: 1e-4,
            ..SolverConfig::default()
        };
        let stats = solve_pressure(
            &nbs,
            &mut grids,
            &DomainBc::all_walls(),
            &params(),
            &RustBackend,
            &cfg,
        );
        assert!(
            stats.final_residual < 1e-3 * stats.initial_residual,
            "{stats:?}"
        );
        assert!(stats.cycles <= 12);
    }

    #[test]
    fn vcycle_beats_plain_smoothing_per_work() {
        // multigrid's whole point: same work budget, far lower residual
        let (nbs, mut g_mg) = setup(2);
        random_rhs(&nbs, &mut g_mg, 9);
        let mut g_sm = g_mg.clone();
        let bc = DomainBc::all_walls();
        let mg = solve_pressure(
            &nbs,
            &mut g_mg,
            &bc,
            &params(),
            &RustBackend,
            &SolverConfig {
                max_cycles: 3,
                rtol: 0.0,
                ..SolverConfig::default()
            },
        );
        // equal number of fine-level-equivalent sweeps, plain smoothing
        let finest = nbs.tree.nodes_at_depth(2);
        let mut scratch = Scratch::default();
        let par = params().at_h(nbs.tree.h_at_depth(2) as f32);
        smooth_level(
            &nbs,
            &mut g_sm,
            &finest,
            2,
            &par,
            &RustBackend,
            &bc,
            mg.sweeps,
            &mut scratch,
        );
        let r_sm = residual_level(
            &nbs,
            &mut g_sm,
            &finest,
            2,
            &par,
            &RustBackend,
            &bc,
            &mut scratch,
        )
        .sqrt();
        assert!(
            mg.final_residual < 0.7 * r_sm,
            "mg {} vs smooth {}",
            mg.final_residual,
            r_sm
        );
    }

    #[test]
    fn adaptive_tree_falls_back_and_reduces() {
        let mut t = SpaceTree::adaptive(BBox::unit(), 2, &|b, _| {
            b.contains_point([0.01, 0.01, 0.01])
        });
        sfc::partition(&mut t, 2);
        let nbs = NeighbourhoodServer::new(t);
        let mut grids: Vec<DGrid> =
            nbs.tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        // rhs on every leaf (its own depth)
        let mut rng = Rng::new(11);
        for (i, n) in nbs.tree.nodes.clone().iter().enumerate() {
            if n.is_leaf() {
                let mut f = vec![0.0f32; DGRID_CELLS];
                rng.fill_f32(&mut f, -1.0, 1.0);
                let mean: f32 = f.iter().sum::<f32>() / f.len() as f32;
                for x in f.iter_mut() {
                    *x -= mean;
                }
                grids[i].temp.set_interior(var::P, &f);
            }
        }
        let stats = solve_pressure(
            &nbs,
            &mut grids,
            &DomainBc::all_walls(),
            &params(),
            &RustBackend,
            &SolverConfig {
                max_cycles: 20,
                ..SolverConfig::default()
            },
        );
        assert!(stats.final_residual < stats.initial_residual, "{stats:?}");
    }

    #[test]
    fn level_exchange_same_level_ghosts() {
        let (nbs, mut grids) = setup(1);
        for (i, g) in grids.iter_mut().enumerate() {
            let f = vec![i as f32; DGRID_CELLS];
            g.cur.set_interior(var::P, &f);
        }
        level_exchange(
            &nbs,
            &mut grids,
            1,
            Gen::Cur,
            var::P,
            &DomainBc::all_walls(),
        );
        let a = nbs
            .tree
            .lookup(crate::tree::uid::LocCode::ROOT.child(0))
            .unwrap();
        let b = nbs
            .tree
            .lookup(crate::tree::uid::LocCode::ROOT.child(0b100))
            .unwrap();
        assert_eq!(
            grids[a as usize].cur.var(var::P)[pidx(DGRID_N + 1, 5, 5)],
            b as f32
        );
    }

    #[test]
    fn solve_is_deterministic() {
        let (nbs, mut g1) = setup(1);
        random_rhs(&nbs, &mut g1, 21);
        let mut g2 = g1.clone();
        let bc = DomainBc::all_walls();
        let cfg = SolverConfig::default();
        let s1 = solve_pressure(&nbs, &mut g1, &bc, &params(), &RustBackend, &cfg);
        let s2 = solve_pressure(&nbs, &mut g2, &bc, &params(), &RustBackend, &cfg);
        assert_eq!(s1.final_residual, s2.final_residual);
        assert_eq!(
            g1[0].cur.var(var::P)[pidx(5, 5, 5)],
            g2[0].cur.var(var::P)[pidx(5, 5, 5)]
        );
    }
}
