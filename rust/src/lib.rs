//! # mpfluid — massively parallel CFD with an efficient HDF5-style I/O kernel
//!
//! Reproduction of Ertl, Frisch & Mundani, *“Design and Optimisation of an
//! Efficient HDF5 I/O Kernel for Massive Parallel Fluid Flow Simulations”*
//! (Concurrency and Computation: Practice and Experience, 2018,
//! DOI 10.1002/cpe.4165).
//!
//! The crate is the Layer-3 (Rust) part of a three-layer stack:
//!
//! * **L1/L2** live in `python/compile/`: Pallas stencil kernels inside a JAX
//!   compute graph, AOT-lowered once to HLO-text artifacts (`make artifacts`).
//! * **L3** (this crate) owns everything at runtime: the space-tree data
//!   structure, neighbourhood server, ghost-layer exchange, the multigrid-like
//!   pressure solver that drives the AOT kernels through PJRT
//!   ([`runtime`]), and — the paper's headline contribution — the parallel
//!   shared-file I/O kernel ([`iokernel`]) with collective buffering
//!   ([`pario`]) over pluggable storage backends ([`h5lite::store`]: direct
//!   synchronous files, or a paged in-memory image whose background flusher
//!   overlaps step N+1's fill with step N's drain)
//!   on a simulated HPC substrate ([`cluster`]), plus the sliding
//!   window ([`window`]) — read through epoch-pinned, cache-carrying
//!   [`window::SnapshotReader`] sessions, fanned out to many concurrent
//!   viewers by [`window::ReaderPool`] + the bounded-worker
//!   [`window::Collector`] over a process-wide deduplicating
//!   [`h5lite::SharedChunkCache`] — with its budget-aware
//!   multi-resolution pyramid ([`lod`]), time-reversible steering
//!   ([`steering`]), and in-transit epoch streaming ([`stream`]): the
//!   paged backend's committed flush batches teed live over TCP, so
//!   remote viewers follow a running simulation byte-identically without
//!   touching the shared file system.
//!
//! Every lock in the concurrent core carries a static rank from the
//! [`sync`] analysis layer (deadlock-freedom checked in debug builds,
//! zero-cost passthrough in release), and the commit/flush, epoch-pin
//! and stream-seeding protocols are exhaustively model-checked by
//! [`sync::model`]; `CONCURRENCY.md` maps every lock family → rank →
//! what it protects → who acquires it with what held.
//!
//! See `DESIGN.md` for the complete system inventory and the experiment
//! index mapping every figure/table of the paper to a bench/example.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod cluster;
pub mod util;
pub mod config;
pub mod coordinator;
pub mod exchange;
pub mod h5lite;
pub mod iokernel;
pub mod lod;
pub mod metrics;
pub mod nbs;
pub mod pario;
pub mod physics;
pub mod runtime;
pub mod solver;
pub mod steering;
pub mod stream;
pub mod sync;
pub mod tree;
pub mod vpic;
pub mod window;

/// Edge length of a d-grid (cells per dimension). The paper fixes this to 16
/// ("each d-grid contains 16 cells in every dimension", §5.3) and so do the
/// AOT artifacts; the Rust code keeps it a constant rather than a generic to
/// match.
pub const DGRID_N: usize = 16;

/// Cells in one d-grid.
pub const DGRID_CELLS: usize = DGRID_N * DGRID_N * DGRID_N;

/// Number of field variables stored per cell (u, v, w, p, T).
pub const NVAR: usize = 5;

/// Variable indices into a [`tree::dgrid::DGrid`] field set.
pub mod var {
    pub const U: usize = 0;
    pub const V: usize = 1;
    pub const W: usize = 2;
    pub const P: usize = 3;
    pub const T: usize = 4;
}
