//! In-transit epoch streaming: committed flush batches, published live.
//!
//! The paper's steering/visualisation front ends round-trip every epoch
//! through the filesystem: the writer commits, the flusher drains, a viewer
//! polls the file and re-opens it. That couples reader latency to
//! writer-disk bandwidth — the file-based bottleneck the openPMD/ADIOS2
//! streaming-transport work (Poeschel et al., arXiv 2107.06108) attacks and
//! the interactive-exploration companion paper (Perović et al.,
//! arXiv 1807.00149) suffers from. This module removes the round trip: the
//! paged backend already turns each commit into an ordered, self-consistent
//! batch sequence ending in a superblock flip, so *publishing an epoch is
//! teeing the batch*.
//!
//! * [`EpochPublisher`] implements [`BatchSink`] and attaches to a paged
//!   [`H5File`] ([`EpochPublisher::attach`]). Every barrier batch is teed —
//!   once, whatever the subscriber count — into per-subscriber bounded
//!   queues and fanned out over TCP by per-subscriber sender threads. The
//!   writer never blocks on a subscriber: when a queue is full the
//!   configured [`SlowConsumerPolicy`] either *coalesces* the queue into
//!   one cumulative frame (latest bytes win) or *disconnects* the laggard.
//! * [`StreamSubscriber`] connects, catches up from the file (copy the
//!   source file — at least the durable prefix — into a local mirror), then
//!   applies stream frames in order onto a [`PagedImage`]-backed mirror of
//!   the writer's image. Reconnect-resync is the same code path: connect
//!   again, catch up from the file again.
//!
//! ## Wire format
//!
//! All integers little-endian. On connect the publisher sends one HELLO:
//!
//! ```text
//! HELLO := magic[8]="MPH5STRM" version:u32 durable_seq:u64 head_seq:u64
//! ```
//!
//! then a stream of BATCH frames, strictly in sequence order:
//!
//! ```text
//! BATCH := kind:u8=1 first_seq:u64 seq:u64 durable_seq:u64 head_seq:u64
//!          set_len:u64 flags:u32 flips:u32 n_ranges:u32
//!          { off:u64 len:u64 bytes[len] } * n_ranges
//! ```
//!
//! `flags` bit 0 = the frame contains a superblock flip (it commits one or
//! more epochs); bit 1 = the frame is a coalesced merge of `first_seq..=seq`
//! (`flips` counts the flips merged in). `durable_seq`/`head_seq` piggyback
//! the publisher's watermarks at send time, giving the subscriber its lag
//! without a back-channel. A frame's ranges carry **absolute contents** at
//! absolute offsets — applying a frame is idempotent, and replaying a frame
//! whose effects are already (even partially, via a torn flush) on disk
//! simply converges the mirror.
//!
//! ## Consistency and resync rules
//!
//! * The publisher retains every batch newer than the flusher's durable
//!   watermark. A new subscriber's queue is seeded with the retained
//!   batches *before* any new batch can be published to it, so the stream
//!   it sees is gapless from the durable watermark onward.
//! * File catch-up: the source file always holds a (possibly torn) prefix
//!   of the batch history that is at least the durable watermark. Copying
//!   it and then applying the retained batches in order overwrites every
//!   byte the copy may have caught mid-flight with its final absolute
//!   content — so after the replay the mirror equals the writer's image at
//!   the publisher's head, byte for byte.
//! * Epoch boundaries: a frame with the flip flag ends one (or more,
//!   if coalesced) epochs. The subscriber barriers its mirror at each flip,
//!   so opening the mirror path with [`H5File::open`] always lands on the
//!   last applied epoch — and because committed extents are never
//!   overwritten in place (chunk extents, the footer, and — since the
//!   epoch-versioned contiguous write-aside — contiguous payloads too),
//!   even a mirror caught mid-frame recovers exactly like a torn flush.
//! * Reconnect after a disconnect (slow-consumer policy, network error,
//!   subscriber crash) is a fresh [`StreamSubscriber::connect`]: the file
//!   catch-up replaces the mirror wholesale, re-entering the stream at the
//!   current watermarks. No server-side per-subscriber state survives.
//!
//! The delivery economics — when following the stream beats polling the
//! file — are priced by [`crate::cluster::Machine::estimate_stream`]; the
//! `stream_follow` bench measures both on the real implementation.

use std::collections::{BTreeMap, VecDeque};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};

use crate::h5lite::store::{BatchSink, PagedImage, Store};
use crate::h5lite::H5File;
use crate::metrics::{names, Metrics};

/// Magic bytes opening the HELLO frame.
pub const STREAM_MAGIC: &[u8; 8] = b"MPH5STRM";
/// Wire protocol version.
pub const STREAM_VERSION: u32 = 1;

const FLAG_FLIP: u32 = 1 << 0;
const FLAG_COALESCED: u32 = 1 << 1;
/// Sanity cap on a single range's length (1 TiB) — a corrupt length field
/// must not become an allocation.
const MAX_RANGE_LEN: u64 = 1 << 40;

/// What to do when a subscriber's bounded send queue is full.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum SlowConsumerPolicy {
    /// Merge a backlog into one cumulative frame: later bytes win,
    /// intermediate epoch deliveries are dropped (counted in
    /// `stream.dropped_batches`), and the subscriber lands on the latest
    /// state when it catches up. Merging normally happens on the laggard's
    /// own sender thread (it drains its whole queue per send); the writer
    /// only merges itself — still never blocking on the socket — when a
    /// sender stuck mid-`write` lets the queue hit its hard cap.
    #[default]
    Coalesce,
    /// Drop the subscriber: its socket closes and it must reconnect
    /// (re-entering through file catch-up). Choose this when a consumer
    /// must see *every* epoch or none.
    Disconnect,
}

/// Tuning for [`EpochPublisher`].
#[derive(Clone)]
pub struct PublisherOptions {
    /// Per-subscriber bound on queued frames before the slow-consumer
    /// policy engages.
    pub max_queued_batches: usize,
    pub policy: SlowConsumerPolicy,
    /// Metrics sink for the `stream.*` gauges/counters.
    pub metrics: Option<Arc<Metrics>>,
}

impl Default for PublisherOptions {
    fn default() -> Self {
        PublisherOptions {
            max_queued_batches: 8,
            policy: SlowConsumerPolicy::default(),
            metrics: None,
        }
    }
}

/// One teed batch, shared (`Arc`) across every subscriber queue. The range
/// contents are the flush queue's own `Arc`-shared snapshots, so publishing
/// costs O(ranges) handle clones on the writer thread — no payload copy at
/// all, whatever the fan-out.
struct Frame {
    first_seq: u64,
    seq: u64,
    set_len: u64,
    flip: bool,
    coalesced: bool,
    /// Superblock flips this frame carries (>1 only when coalesced).
    flips: u32,
    ranges: Vec<(u64, Arc<Vec<u8>>)>,
    bytes: u64,
}

/// Overlay-insert `[off, off+data.len())` into a map of non-overlapping
/// ranges: overlapping parts of existing entries are trimmed away, so later
/// inserts win — the merge rule behind [`SlowConsumerPolicy::Coalesce`].
fn overlay_insert(map: &mut BTreeMap<u64, Vec<u8>>, off: u64, data: &[u8]) {
    if data.is_empty() {
        return;
    }
    let end = off + data.len() as u64;
    // entries are mutually non-overlapping and sorted by start, so their
    // ends are sorted too: walk backwards from the last entry starting
    // before `end` until one ends at or before `off`
    let hit: Vec<u64> = map
        .range(..end)
        .rev()
        .take_while(|(&o, v)| o + v.len() as u64 > off)
        .map(|(&o, _)| o)
        .collect();
    for o in hit {
        let v = map.remove(&o).unwrap();
        let vend = o + v.len() as u64;
        if o < off {
            map.insert(o, v[..(off - o) as usize].to_vec());
        }
        if vend > end {
            map.insert(end, v[(end - o) as usize..].to_vec());
        }
    }
    map.insert(off, data.to_vec());
}

/// Distinct epoch deliveries lost by merging `frames` into one: every
/// flip-bearing frame was one observable epoch edge, the merge leaves one.
/// (Merging a commit's own footer batch into its flip batch loses nothing
/// and counts zero.)
fn flip_deliveries_merged(frames: &[Arc<Frame>]) -> u64 {
    (frames.iter().filter(|f| f.flips > 0).count() as u64).saturating_sub(1)
}

/// Merge queued frames (oldest first) into one cumulative frame.
fn merge_frames(frames: &[Arc<Frame>]) -> Frame {
    debug_assert!(!frames.is_empty());
    let mut map: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
    let mut set_len = 0u64;
    let mut flips = 0u32;
    for f in frames {
        set_len = set_len.max(f.set_len);
        flips += f.flips;
        for (off, data) in &f.ranges {
            overlay_insert(&mut map, *off, data);
        }
    }
    let mut bytes = 0u64;
    let ranges: Vec<(u64, Arc<Vec<u8>>)> = map
        .into_iter()
        .inspect(|(_, d)| bytes += d.len() as u64)
        .map(|(o, d)| (o, Arc::new(d)))
        .collect();
    Frame {
        first_seq: frames[0].first_seq,
        seq: frames[frames.len() - 1].seq,
        set_len,
        flip: flips > 0,
        coalesced: true,
        flips,
        ranges,
        bytes,
    }
}

/// One subscriber's bounded send queue, shared between the publish tee
/// (pushes) and that subscriber's sender thread (pops).
struct SubSlot {
    queue: VecDeque<Arc<Frame>>,
    /// Queued flips, maintained with the queue (the lag-epochs gauge).
    queued_flips: u64,
    queued_bytes: u64,
    dead: bool,
}

type Slot = Arc<(OrderedMutex<SubSlot>, OrderedCondvar)>;

struct PubInner {
    subs: Vec<Slot>,
    /// Batches newer than the flusher's durable watermark — the replay a
    /// new subscriber needs on top of its file catch-up.
    retained: VecDeque<Arc<Frame>>,
}

/// Shared state behind [`EpochPublisher`]: the accept loop and the sender
/// threads hold this (not the publisher itself), so the publisher can be
/// dropped independently of in-flight connections.
struct PubShared {
    opts: PublisherOptions,
    inner: OrderedMutex<PubInner>,
    stop: AtomicBool,
    head_seq: AtomicU64,
    durable_seq: AtomicU64,
    publish_ns: AtomicU64,
    published_bytes: AtomicU64,
    dropped_batches: AtomicU64,
    subscribers: AtomicU64,
}

impl PubShared {
    fn metrics(&self) -> Option<&Arc<Metrics>> {
        self.opts.metrics.as_ref()
    }

    /// Push a frame onto one subscriber queue, applying the slow-consumer
    /// policy at the hard cap. Returns epoch deliveries dropped (merged
    /// away or discarded).
    fn push_frame(&self, slot: &Slot, frame: Arc<Frame>) -> u64 {
        let (m, cv) = &**slot;
        let mut s = m.lock().unwrap();
        if s.dead {
            return 0;
        }
        let mut dropped = 0u64;
        if s.queue.len() >= self.opts.max_queued_batches.max(1) {
            match self.opts.policy {
                SlowConsumerPolicy::Disconnect => {
                    // every queued epoch plus the incoming one goes
                    // undelivered (the subscriber must reconnect and
                    // catch up from the file)
                    dropped = s.queued_flips + frame.flips as u64;
                    s.queue.clear();
                    s.queued_flips = 0;
                    s.queued_bytes = 0;
                    s.dead = true;
                    cv.notify_all();
                    return dropped;
                }
                SlowConsumerPolicy::Coalesce => {
                    let mut all: Vec<Arc<Frame>> = s.queue.drain(..).collect();
                    all.push(frame);
                    dropped = flip_deliveries_merged(&all);
                    let merged = Arc::new(merge_frames(&all));
                    s.queued_flips = merged.flips as u64;
                    s.queued_bytes = merged.bytes;
                    s.queue.push_back(merged);
                }
            }
        } else {
            s.queued_flips += frame.flips as u64;
            s.queued_bytes += frame.bytes;
            s.queue.push_back(frame);
        }
        cv.notify_all();
        dropped
    }

    /// Refresh the `stream.*` gauges from the current queue states.
    fn refresh_gauges(&self, inner: &PubInner) {
        let Some(metrics) = self.metrics() else {
            return;
        };
        let mut lag_flips = 0u64;
        let mut lag_bytes = 0u64;
        let mut live = 0u64;
        for slot in &inner.subs {
            let s = slot.0.lock().unwrap();
            if s.dead {
                continue;
            }
            live += 1;
            lag_flips = lag_flips.max(s.queued_flips);
            lag_bytes = lag_bytes.max(s.queued_bytes);
        }
        metrics.set_gauge(names::STREAM_SUBSCRIBERS, live as f64);
        metrics.set_gauge(names::STREAM_LAG_EPOCHS, lag_flips as f64);
        metrics.set_gauge(names::STREAM_LAG_BYTES, lag_bytes as f64);
    }
}

/// Counter snapshot of a publisher (see [`EpochPublisher::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct PublishStats {
    /// Live subscribers.
    pub subscribers: u64,
    /// Wall time spent inside the publish tee (on the writer's commit
    /// path — the `IoReport.publish_seconds` input).
    pub publish_seconds: f64,
    /// Payload bytes teed (once per batch, whatever the fan-out).
    pub published_bytes: u64,
    /// Slowest live subscriber's queued payload bytes.
    pub backlog_bytes: u64,
    /// Distinct epoch deliveries coalesced away or discarded by the
    /// slow-consumer policy (a commit's footer batch merging into its own
    /// flip batch loses nothing and is not counted).
    pub dropped_batches: u64,
    /// Latest published batch sequence.
    pub head_seq: u64,
    /// Latest durably flushed batch sequence.
    pub durable_seq: u64,
}

/// The writer-side tee: a [`BatchSink`] that fans committed flush batches
/// out to TCP subscribers. See the module docs for the protocol.
pub struct EpochPublisher {
    shared: Arc<PubShared>,
    addr: SocketAddr,
    accept: OrderedMutex<Option<JoinHandle<()>>>,
}

impl EpochPublisher {
    /// Bind a publisher on `addr` (use port 0 for an ephemeral port; see
    /// [`EpochPublisher::local_addr`]) and start its accept loop. Attach it
    /// to a paged-backed file with [`EpochPublisher::attach`].
    pub fn bind<A: ToSocketAddrs>(addr: A, opts: PublisherOptions) -> Result<Arc<EpochPublisher>> {
        let listener = TcpListener::bind(addr).context("stream: bind publisher")?;
        let addr = listener.local_addr().context("stream: local_addr")?;
        let shared = Arc::new(PubShared {
            opts,
            inner: OrderedMutex::new(LockRank::PubInner, PubInner {
                subs: Vec::new(),
                retained: VecDeque::new(),
            }),
            stop: AtomicBool::new(false),
            head_seq: AtomicU64::new(0),
            durable_seq: AtomicU64::new(0),
            publish_ns: AtomicU64::new(0),
            published_bytes: AtomicU64::new(0),
            dropped_batches: AtomicU64::new(0),
            subscribers: AtomicU64::new(0),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("stream-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .context("stream: spawn accept loop")?;
        Ok(Arc::new(EpochPublisher {
            shared,
            addr,
            accept: OrderedMutex::new(LockRank::PubAccept, Some(accept)),
        }))
    }

    /// The bound address subscribers connect to.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Tee `file`'s flush batches through this publisher. Fails on the
    /// direct backend — synchronous writes have no batch stream to tee.
    pub fn attach(self: &Arc<Self>, file: &H5File) -> Result<()> {
        let sink: Arc<dyn BatchSink> = Arc::clone(self) as Arc<dyn BatchSink>;
        if !file.set_batch_sink(Some(sink)) {
            bail!("stream: publishing needs the paged backend (direct I/O has no batch stream)");
        }
        Ok(())
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PublishStats {
        let inner = self.shared.inner.lock().unwrap();
        let mut backlog = 0u64;
        let mut live = 0u64;
        for slot in &inner.subs {
            let s = slot.0.lock().unwrap();
            if !s.dead {
                live += 1;
                backlog = backlog.max(s.queued_bytes);
            }
        }
        PublishStats {
            subscribers: live,
            publish_seconds: self.shared.publish_ns.load(Ordering::Relaxed) as f64 / 1e9,
            published_bytes: self.shared.published_bytes.load(Ordering::Relaxed),
            backlog_bytes: backlog,
            dropped_batches: self.shared.dropped_batches.load(Ordering::Relaxed),
            head_seq: self.shared.head_seq.load(Ordering::Relaxed),
            durable_seq: self.shared.durable_seq.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting, close every subscriber and join the accept loop.
    /// Idempotent; also runs on drop. Detach the publisher from the file
    /// (`file.set_batch_sink(None)`) before or after — a stopped publisher
    /// swallows further batches without error.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        {
            let inner = self.shared.inner.lock().unwrap();
            for slot in &inner.subs {
                let (m, cv) = &**slot;
                m.lock().unwrap().dead = true;
                cv.notify_all();
            }
        }
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        // take the handle, drop the guard, THEN join: joining while the
        // accept-handle lock is held would deadlock a concurrent shutdown
        // (idempotency is part of the contract) the moment the joined
        // thread — or anything it wakes — touches the same lock
        let handle = self.accept.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for EpochPublisher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl BatchSink for EpochPublisher {
    fn on_batch(&self, seq: u64, set_len: u64, ranges: &[(u64, Arc<Vec<u8>>)]) {
        let shared = &self.shared;
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        let t0 = Instant::now();
        let mut bytes = 0u64;
        let frame = Arc::new(Frame {
            first_seq: seq,
            seq,
            set_len,
            // commit issues the superblock write alone between barriers, so
            // a flip batch is exactly the one whose ranges reach offset 0
            flip: ranges.iter().any(|&(off, _)| off == 0),
            coalesced: false,
            flips: ranges.iter().any(|&(off, _)| off == 0) as u32,
            ranges: ranges
                .iter()
                .map(|(off, data)| {
                    bytes += data.len() as u64;
                    (*off, data.clone())
                })
                .collect(),
            bytes,
        });
        shared.head_seq.store(seq, Ordering::Relaxed);
        shared.published_bytes.fetch_add(frame.bytes, Ordering::Relaxed);
        let mut inner = shared.inner.lock().unwrap();
        inner.retained.push_back(Arc::clone(&frame));
        let mut dropped = 0u64;
        for slot in &inner.subs {
            dropped += shared.push_frame(slot, Arc::clone(&frame));
        }
        inner.subs.retain(|s| !s.0.lock().unwrap().dead);
        shared.subscribers.store(inner.subs.len() as u64, Ordering::Relaxed);
        if dropped > 0 {
            shared.dropped_batches.fetch_add(dropped, Ordering::Relaxed);
            if let Some(m) = shared.metrics() {
                m.add(names::STREAM_DROPPED_BATCHES, dropped);
            }
        }
        shared.refresh_gauges(&inner);
        drop(inner);
        shared
            .publish_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    fn on_durable(&self, seq: u64) {
        let shared = &self.shared;
        shared.durable_seq.store(seq, Ordering::Relaxed);
        let mut inner = shared.inner.lock().unwrap();
        // batches at or below the durable watermark are on disk: a new
        // subscriber's file catch-up covers them, so retention can let go
        while inner.retained.front().map_or(false, |f| f.seq <= seq) {
            inner.retained.pop_front();
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<PubShared>) {
    loop {
        let sock = match listener.accept() {
            Ok((sock, _)) => sock,
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        if sock.set_nodelay(true).is_err() {
            continue;
        }
        let slot: Slot = Arc::new((
            OrderedMutex::new(LockRank::SubSlot, SubSlot {
                queue: VecDeque::new(),
                queued_flips: 0,
                queued_bytes: 0,
                dead: false,
            }),
            OrderedCondvar::new(),
        ));
        // Register under the inner lock and seed the queue with the
        // retained batches in the same critical section: no batch published
        // after this point can be missed, none retained can be skipped —
        // the stream is gapless from the durable watermark on.
        {
            let mut inner = shared.inner.lock().unwrap();
            for f in &inner.retained {
                shared.push_frame(&slot, Arc::clone(f));
            }
            inner.subs.push(Arc::clone(&slot));
            shared.subscribers.store(inner.subs.len() as u64, Ordering::Relaxed);
            shared.refresh_gauges(&inner);
        }
        let send_shared = Arc::clone(&shared);
        let _ = std::thread::Builder::new()
            .name("stream-send".into())
            .spawn(move || sender_loop(sock, slot, send_shared));
    }
}

fn sender_loop(mut sock: TcpStream, slot: Slot, shared: Arc<PubShared>) {
    // HELLO first: watermarks at registration time
    let mut hello = Vec::with_capacity(28);
    hello.extend_from_slice(STREAM_MAGIC);
    hello.extend_from_slice(&STREAM_VERSION.to_le_bytes());
    hello.extend_from_slice(&shared.durable_seq.load(Ordering::Relaxed).to_le_bytes());
    hello.extend_from_slice(&shared.head_seq.load(Ordering::Relaxed).to_le_bytes());
    let mut alive = sock.write_all(&hello).is_ok() && sock.flush().is_ok();
    while alive {
        // Drain everything queued in one pop. Under `Coalesce` a backlog is
        // merged *here*, on the subscriber's own sender thread — the writer
        // only pays the merge itself when this thread is stuck inside a
        // blocked `write` long enough for the queue to hit its hard cap.
        let pending: Vec<Arc<Frame>> = {
            let (m, cv) = &*slot;
            let mut s = m.lock().unwrap();
            loop {
                if s.dead || shared.stop.load(Ordering::Relaxed) {
                    alive = false;
                    break Vec::new();
                }
                if !s.queue.is_empty() {
                    let take = match shared.opts.policy {
                        SlowConsumerPolicy::Coalesce => s.queue.len(),
                        // without coalescing every frame ships individually
                        SlowConsumerPolicy::Disconnect => 1,
                    };
                    let drained: Vec<Arc<Frame>> = s.queue.drain(..take).collect();
                    for f in &drained {
                        s.queued_flips = s.queued_flips.saturating_sub(f.flips as u64);
                        s.queued_bytes = s.queued_bytes.saturating_sub(f.bytes);
                    }
                    break drained;
                }
                s = cv.wait(s).unwrap();
            }
        };
        if pending.is_empty() {
            break;
        }
        let frame = if pending.len() == 1 {
            Arc::clone(&pending[0])
        } else {
            let dropped = flip_deliveries_merged(&pending);
            if dropped > 0 {
                shared.dropped_batches.fetch_add(dropped, Ordering::Relaxed);
                if let Some(m) = shared.metrics() {
                    m.add(names::STREAM_DROPPED_BATCHES, dropped);
                }
            }
            Arc::new(merge_frames(&pending))
        };
        if write_frame(&mut sock, &frame, &shared).is_err() {
            alive = false;
        }
    }
    let _ = sock.shutdown(Shutdown::Both);
    let (m, _) = &*slot;
    m.lock().unwrap().dead = true;
    // the publish tee prunes dead slots on its next batch; refresh the
    // subscriber gauge eagerly so a disconnect is visible without traffic
    let inner = shared.inner.lock().unwrap();
    shared.refresh_gauges(&inner);
}

fn write_frame(sock: &mut TcpStream, frame: &Frame, shared: &PubShared) -> std::io::Result<()> {
    let mut head = Vec::with_capacity(53);
    head.push(1u8);
    head.extend_from_slice(&frame.first_seq.to_le_bytes());
    head.extend_from_slice(&frame.seq.to_le_bytes());
    head.extend_from_slice(&shared.durable_seq.load(Ordering::Relaxed).to_le_bytes());
    head.extend_from_slice(&shared.head_seq.load(Ordering::Relaxed).to_le_bytes());
    head.extend_from_slice(&frame.set_len.to_le_bytes());
    let mut flags = 0u32;
    if frame.flip {
        flags |= FLAG_FLIP;
    }
    if frame.coalesced {
        flags |= FLAG_COALESCED;
    }
    head.extend_from_slice(&flags.to_le_bytes());
    head.extend_from_slice(&frame.flips.to_le_bytes());
    head.extend_from_slice(&(frame.ranges.len() as u32).to_le_bytes());
    sock.write_all(&head)?;
    for (off, data) in &frame.ranges {
        sock.write_all(&off.to_le_bytes())?;
        sock.write_all(&(data.len() as u64).to_le_bytes())?;
        sock.write_all(data)?;
    }
    sock.flush()
}

// ---------------------------------------------------------------------------
// Subscriber
// ---------------------------------------------------------------------------

fn rd_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("stream: short read")?;
    Ok(u32::from_le_bytes(b))
}

fn rd_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("stream: short read")?;
    Ok(u64::from_le_bytes(b))
}

/// A decoded BATCH frame (subscriber side).
struct WireFrame {
    seq: u64,
    durable_seq: u64,
    head_seq: u64,
    set_len: u64,
    flip: bool,
    flips: u32,
    ranges: Vec<(u64, Vec<u8>)>,
}

fn read_frame(r: &mut impl Read) -> Result<WireFrame> {
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind).context("stream: closed")?;
    if kind[0] != 1 {
        bail!("stream: unknown frame kind {}", kind[0]);
    }
    let _first_seq = rd_u64(r)?;
    let seq = rd_u64(r)?;
    let durable_seq = rd_u64(r)?;
    let head_seq = rd_u64(r)?;
    let set_len = rd_u64(r)?;
    let flags = rd_u32(r)?;
    let flips = rd_u32(r)?;
    let n_ranges = rd_u32(r)?;
    let mut ranges = Vec::with_capacity(n_ranges as usize);
    for _ in 0..n_ranges {
        let off = rd_u64(r)?;
        let len = rd_u64(r)?;
        if len > MAX_RANGE_LEN {
            bail!("stream: absurd range length {len}");
        }
        let mut data = vec![0u8; len as usize];
        r.read_exact(&mut data).context("stream: short range")?;
        ranges.push((off, data));
    }
    Ok(WireFrame {
        seq,
        durable_seq,
        head_seq,
        set_len,
        flip: flags & FLAG_FLIP != 0,
        flips,
        ranges,
    })
}

/// Live progress of a [`StreamSubscriber`] (see
/// [`StreamSubscriber::progress`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SubscriberProgress {
    /// Last applied batch sequence.
    pub last_seq: u64,
    /// Epochs (superblock flips) applied since connect.
    pub epochs_applied: u64,
    /// Publisher's durable watermark, as last piggybacked.
    pub durable_seq: u64,
    /// Publisher's head, as last piggybacked.
    pub head_seq: u64,
}

impl SubscriberProgress {
    /// Batches published but not yet applied here — the staleness bound.
    pub fn lag_seqs(&self) -> u64 {
        self.head_seq.saturating_sub(self.last_seq)
    }
}

struct SubState {
    progress: SubscriberProgress,
    /// Why the apply loop ended, if it did (clean shutdown = "closed").
    dead: Option<String>,
}

/// The reader-side endpoint: applies stream frames in order onto a
/// [`PagedImage`]-backed local mirror of the writer's file, so
/// [`H5File::open`] on the mirror path follows the live run with bounded
/// staleness. See the module docs for the catch-up/resync rules.
pub struct StreamSubscriber {
    mirror: PathBuf,
    store: Arc<PagedImage>,
    state: Arc<(OrderedMutex<SubState>, OrderedCondvar)>,
    sock: TcpStream,
    apply: OrderedMutex<Option<JoinHandle<()>>>,
}

impl StreamSubscriber {
    /// Connect to a publisher at `addr`, catch up from `source` (the
    /// writer's file — readable at least up to the durable watermark) into
    /// `mirror`, and start following the stream. Reconnecting after any
    /// disconnect is simply calling this again with the same paths.
    pub fn connect<A: ToSocketAddrs>(
        addr: A,
        source: &Path,
        mirror: &Path,
    ) -> Result<StreamSubscriber> {
        let mut sock = TcpStream::connect(addr).context("stream: connect")?;
        sock.set_nodelay(true).ok();
        // HELLO before the copy: every batch beyond the durable watermark
        // is now queued for us, so the copy below can race the flusher
        // freely — whatever it half-captures, the replay overwrites
        let mut magic = [0u8; 8];
        sock.read_exact(&mut magic).context("stream: no hello")?;
        if &magic != STREAM_MAGIC {
            bail!("stream: bad magic in hello");
        }
        let version = rd_u32(&mut sock)?;
        if version != STREAM_VERSION {
            bail!("stream: protocol version {version}, expected {STREAM_VERSION}");
        }
        let durable_seq = rd_u64(&mut sock)?;
        let head_seq = rd_u64(&mut sock)?;
        std::fs::copy(source, mirror).context("stream: file catch-up copy")?;
        let store = Arc::new(PagedImage::open(mirror).context("stream: open mirror")?);
        let state = Arc::new((
            OrderedMutex::new(LockRank::SubscriberState, SubState {
                progress: SubscriberProgress {
                    last_seq: durable_seq,
                    epochs_applied: 0,
                    durable_seq,
                    head_seq,
                },
                dead: None,
            }),
            OrderedCondvar::new(),
        ));
        let apply_sock = sock.try_clone().context("stream: clone socket")?;
        let apply_store = Arc::clone(&store);
        let apply_state = Arc::clone(&state);
        let apply = std::thread::Builder::new()
            .name("stream-apply".into())
            .spawn(move || apply_loop(apply_sock, apply_store, apply_state))
            .context("stream: spawn apply loop")?;
        Ok(StreamSubscriber {
            mirror: mirror.to_path_buf(),
            store,
            state,
            sock,
            apply: OrderedMutex::new(LockRank::SubApplyHandle, Some(apply)),
        })
    }

    /// Path of the mirror file readers open.
    pub fn mirror_path(&self) -> &Path {
        &self.mirror
    }

    /// Current apply progress and piggybacked publisher watermarks.
    pub fn progress(&self) -> SubscriberProgress {
        self.state.0.lock().unwrap().progress
    }

    /// Why the stream ended, if it did.
    pub fn dead(&self) -> Option<String> {
        self.state.0.lock().unwrap().dead.clone()
    }

    /// Block until at least `epochs` superblock flips have been applied
    /// since connect (or the stream dies / `timeout` passes). Returns the
    /// epochs applied so far.
    pub fn wait_for_epochs(&self, epochs: u64, timeout: Duration) -> Result<u64> {
        let deadline = Instant::now() + timeout;
        let (m, cv) = &*self.state;
        let mut s = m.lock().unwrap();
        loop {
            if s.progress.epochs_applied >= epochs {
                return Ok(s.progress.epochs_applied);
            }
            if let Some(why) = &s.dead {
                bail!("stream: ended after {} epochs: {why}", s.progress.epochs_applied);
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                bail!(
                    "stream: timed out at {} epochs (wanted {epochs})",
                    s.progress.epochs_applied
                );
            }
            (s, _) = cv.wait_timeout(s, left).map(|(g, t)| (g, t.timed_out())).unwrap();
        }
    }

    /// Open the mirror at its latest applied epoch: flush the mirror image
    /// and open the path like any snapshot file. The handle is an ordinary
    /// epoch-consistent [`H5File`] — it does *not* advance with the stream;
    /// re-open to follow (the `window`/`steering` integration does exactly
    /// that, re-opening per served epoch, ≤ 1 epoch behind the wire).
    pub fn open_file(&self) -> Result<H5File> {
        self.store.barrier().context("stream: mirror barrier")?;
        self.store.wait_durable().context("stream: mirror flush")?;
        H5File::open(&self.mirror)
    }
}

fn apply_loop(sock: TcpStream, store: Arc<PagedImage>, state: Arc<(OrderedMutex<SubState>, OrderedCondvar)>) {
    let mut r = std::io::BufReader::new(sock);
    loop {
        let frame = match read_frame(&mut r) {
            Ok(f) => f,
            Err(e) => {
                let (m, cv) = &*state;
                m.lock().unwrap().dead = Some(e.to_string());
                cv.notify_all();
                return;
            }
        };
        let applied = (|| -> Result<()> {
            store.set_len_min(frame.set_len)?;
            for (off, data) in &frame.ranges {
                store.write_all_at(data, *off)?;
            }
            if frame.flip {
                // barrier at the epoch edge: the mirror file on disk
                // converges to this epoch, so H5File::open on the mirror
                // path lands here (wait_durable is deferred to open_file)
                store.barrier()?;
            }
            Ok(())
        })();
        let (m, cv) = &*state;
        let mut s = m.lock().unwrap();
        match applied {
            Ok(()) => {
                s.progress.last_seq = frame.seq;
                s.progress.durable_seq = frame.durable_seq;
                s.progress.head_seq = frame.head_seq.max(frame.seq);
                s.progress.epochs_applied += frame.flips as u64;
            }
            Err(e) => {
                s.dead = Some(format!("apply failed: {e}"));
                cv.notify_all();
                return;
            }
        }
        cv.notify_all();
    }
}

impl Drop for StreamSubscriber {
    fn drop(&mut self) {
        let _ = self.sock.shutdown(Shutdown::Both);
        // take-then-join outside the handle lock (see EpochPublisher::shutdown)
        let handle = self.apply.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
        // dropping `store` issues the mirror's final barrier and joins its
        // flusher, leaving the mirror file openable at the last applied epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::h5lite::{codec, Backing, Dtype};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("stream_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn overlay_insert_later_bytes_win() {
        let mut m = BTreeMap::new();
        overlay_insert(&mut m, 10, &[1u8; 10]); // [10,20)
        overlay_insert(&mut m, 15, &[2u8; 10]); // [15,25) overrides tail
        overlay_insert(&mut m, 0, &[3u8; 12]); // [0,12) overrides head
        let flat: Vec<(u64, Vec<u8>)> = m.into_iter().collect();
        let mut img = vec![0u8; 25];
        for (o, d) in &flat {
            img[*o as usize..*o as usize + d.len()].copy_from_slice(d);
        }
        let mut want = vec![0u8; 25];
        want[10..20].fill(1);
        want[15..25].fill(2);
        want[0..12].fill(3);
        assert_eq!(img, want);
    }

    #[test]
    fn merge_frames_counts_flips_and_keeps_latest() {
        let a = Arc::new(Frame {
            first_seq: 3,
            seq: 3,
            set_len: 100,
            flip: true,
            coalesced: false,
            flips: 1,
            ranges: vec![(0, Arc::new(vec![1u8; 8]))],
            bytes: 8,
        });
        let b = Arc::new(Frame {
            first_seq: 4,
            seq: 4,
            set_len: 200,
            flip: true,
            coalesced: false,
            flips: 1,
            ranges: vec![(0, Arc::new(vec![2u8; 8])), (50, Arc::new(vec![9u8; 4]))],
            bytes: 12,
        });
        let m = merge_frames(&[a, b]);
        assert_eq!((m.first_seq, m.seq), (3, 4));
        assert_eq!(m.set_len, 200);
        assert!(m.flip && m.coalesced);
        assert_eq!(m.flips, 2);
        assert_eq!(m.ranges[0], (0, Arc::new(vec![2u8; 8])), "later frame wins");
        assert_eq!(m.bytes, 12);
    }

    #[test]
    fn loopback_follow_one_writer_one_subscriber() {
        let src = tmp("follow_src");
        let mir = tmp("follow_mir");
        let metrics = Arc::new(Metrics::new());
        let publisher = EpochPublisher::bind(
            "127.0.0.1:0",
            PublisherOptions {
                metrics: Some(Arc::clone(&metrics)),
                ..PublisherOptions::default()
            },
        )
        .unwrap();
        let mut f = H5File::create_backed(&src, 1, Backing::Paged).unwrap();
        publisher.attach(&f).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::F32, &[8, 4]).unwrap();
        let sub = StreamSubscriber::connect(publisher.local_addr(), &src, &mir).unwrap();
        for step in 1..=3u64 {
            let vals: Vec<f32> = (0..32).map(|i| (step * 100 + i) as f32).collect();
            f.write_all_f32(&ds, &vals).unwrap();
            f.commit().unwrap();
        }
        sub.wait_for_epochs(3, Duration::from_secs(10)).unwrap();
        let rf = sub.open_file().unwrap();
        let rds = rf.dataset("/g", "d").unwrap();
        let got = codec::bytes_to_f32s(&rf.read_rows(&rds, 0, 8).unwrap());
        assert_eq!(got[0], 300.0, "mirror must hold the last epoch");
        assert!(metrics.gauge(names::STREAM_SUBSCRIBERS) >= 1.0);
        // quiesced: mirror and source byte-identical
        f.wait_durable().unwrap();
        drop(rf);
        drop(sub);
        drop(f);
        publisher.shutdown();
        assert_eq!(
            std::fs::read(&src).unwrap(),
            std::fs::read(&mir).unwrap(),
            "quiesced mirror must be byte-identical to the file"
        );
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&mir).ok();
    }

    #[test]
    fn concurrent_publisher_shutdowns_complete_in_bounded_time() {
        // Regression: shutdown() used to join the accept thread while
        // holding the accept-handle lock, so two concurrent shutdowns —
        // idempotency is part of the contract, and Drop also calls
        // shutdown — could deadlock. Race two and watchdog both.
        let src = tmp("shutdown_src");
        let mir = tmp("shutdown_mir");
        let publisher = Arc::new(
            EpochPublisher::bind("127.0.0.1:0", PublisherOptions::default()).unwrap(),
        );
        let mut f = H5File::create_backed(&src, 1, Backing::Paged).unwrap();
        publisher.attach(&f).unwrap();
        let ds = f.create_dataset("/g", "d", Dtype::F32, &[4, 2]).unwrap();
        let sub = StreamSubscriber::connect(publisher.local_addr(), &src, &mir).unwrap();
        f.write_all_f32(&ds, &[1.0; 8]).unwrap();
        f.commit().unwrap();
        sub.wait_for_epochs(1, Duration::from_secs(10)).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..2 {
            let p = Arc::clone(&publisher);
            let tx = tx.clone();
            std::thread::spawn(move || {
                p.shutdown();
                tx.send(()).unwrap();
            });
        }
        for _ in 0..2 {
            rx.recv_timeout(Duration::from_secs(10))
                .expect("concurrent shutdown deadlocked (join-under-lock regression)");
        }
        // the subscriber observes the dead stream and its own Drop joins
        // the apply thread without the publisher's help
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while sub.dead().is_none() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(sub.dead().is_some(), "subscriber must observe shutdown");
        drop(sub);
        drop(f);
        std::fs::remove_file(&src).ok();
        std::fs::remove_file(&mir).ok();
    }
}
