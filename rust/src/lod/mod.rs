//! **lod** — the multi-resolution pyramid for budgeted interactive
//! exploration (Perović et al., arXiv:1807.00149).
//!
//! The snapshot's `current_cell_data` stores every grid at its native
//! resolution, so a whole-domain sliding-window query must either read all
//! leaf grids (blowing any realistic byte budget) or fall back to whatever
//! restricted values the simulation happened to keep in interior rows. This
//! module adds an **octree-style resolution pyramid** derived
//! deterministically from the *written* cell data: level 1 downsamples the
//! finest leaves 2× per axis (each 2×2×2 cell block folds to its mean),
//! level 2 downsamples level 1, and so on down to a single 16³ d-grid
//! covering the whole domain. A reader can then serve any region of
//! interest at the finest level whose cover fits a byte budget, and refine
//! progressively.
//!
//! ## Construction (write side)
//!
//! The pyramid is folded **during** the collective write (Jin et al.,
//! arXiv:2206.14761: derived data is nearly free when it rides the parallel
//! write pipeline): [`PyramidBuilder::fold_rows`] is called by the
//! `pario` aggregators on their own threads as they assemble each chunk of
//! the source dataset — every depth-`D` leaf row folds 2× into its octant
//! of a level-1 grid, and an adaptive tree's coarser leaf at depth `d < D`
//! lands verbatim in level `D − d` (its cells *are* that resolution).
//! Distinct rows write disjoint cell regions, so the fold needs no locks.
//! [`PyramidBuilder::finish`] then folds level `ℓ−1 → ℓ` for the remaining
//! interior levels (cheap: the whole pyramid is ≤ 1/7 of the source), and
//! [`PyramidBuilder::write`] stores the levels as ordinary chunked +
//! compressed datasets.
//!
//! ## On-disk layout (the LOD metadata record)
//!
//! ```text
//! /simulation/t=<t>/lod            @levels @source @fold @row_elems
//!     level_<ℓ>_cells   f32[n_ℓ, 5·16³]   chunked+compressed cell data
//!     level_<ℓ>_locs    u64[n_ℓ]          location code per row (Morton
//!                                          order; depth = levels − ℓ)
//! ```
//!
//! The record is plain groups/attributes/datasets, so it needs no format
//! version bump: a v2.1 file without a `lod` group opens and answers window
//! queries exactly as before, older readers simply ignore the extra group,
//! and [`crate::h5lite::H5File::verify`] accounts pyramid extents like any
//! other live data.
//!
//! ## Invariants
//!
//! * Every stored level-`ℓ` grid that is not a coarse leaf's verbatim copy
//!   has all 8 children stored at level `ℓ−1` (or, for `ℓ = 1`, in the
//!   source rows), and each of its cells equals [`fold_octant`]'s mean of
//!   the corresponding 2×2×2 child cells — property-tested.
//! * Level `levels` (the root) always holds exactly one grid, so a reader
//!   can answer any query with at least one row.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::h5lite::codec::Codec;
use crate::h5lite::{codec, Attr, Dataset, Dtype, H5File, FORMAT_V2};
use crate::iokernel::{CHUNK_ROWS, ROW_BYTES, ROW_ELEMS};
use crate::tree::dgrid::iidx;
use crate::tree::sfc::Partition;
use crate::tree::uid::LocCode;
use crate::tree::{BBox, SpaceTree};
use crate::util::{parallel_for, SendPtr};
use crate::{DGRID_CELLS, DGRID_N, NVAR};

/// Name of the pyramid subgroup inside a timestep group.
pub const LOD_GROUP: &str = "lod";

/// What one source row contributes to the accumulation buffers.
#[derive(Clone, Copy)]
enum RowTarget {
    /// A finest-depth leaf: 2× downsample into `octant` of level-1 row
    /// `level_row`.
    Fold { level_row: usize, octant: u8 },
    /// A coarser leaf of an adaptive tree: verbatim copy into row
    /// `level_row` of `levels[level_ix]` (its native resolution).
    Direct { level_ix: usize, level_row: usize },
}

/// One pyramid level's accumulation buffer (level `ix + 1`, tree depth
/// `max_depth − (ix + 1)`). Rows are Morton-ordered by location code;
/// `data` is written disjointly by the aggregator threads through `ptr`.
struct LevelBuf {
    /// Sorted by code; row `i` holds the grid at `locs[i]`.
    locs: Vec<LocCode>,
    row_of: HashMap<u32, usize>,
    /// Rows that are a coarse leaf's verbatim copy (not a fold of 8
    /// children).
    direct: Vec<bool>,
    data: Vec<f32>,
    /// Raw view of `data` for the lock-free disjoint writes of the fill
    /// phase (the Vec itself is never resized after construction).
    ptr: SendPtr<f32>,
}

/// Report of one pyramid write (part of
/// [`crate::iokernel::SnapshotReport`]).
#[derive(Clone, Copy, Debug)]
pub struct LodWriteReport {
    /// Pyramid levels stored (= the tree depth of the finest leaves).
    pub levels: u32,
    /// Raw pyramid payload bytes (all levels' cell data).
    pub raw_bytes: u64,
    /// Bytes the pyramid physically occupies on disk (compressed cell
    /// extents + the location indexes) — the storage overhead the
    /// acceptance criterion bounds.
    pub stored_bytes: u64,
    /// Wall-clock seconds spent encoding + writing the level datasets
    /// (the fold itself is accounted in
    /// [`crate::pario::IoReport::lod_seconds`]).
    pub write_seconds: f64,
}

/// Accumulates the resolution pyramid of one snapshot while the collective
/// write streams the source rows past it. Shared by reference across the
/// aggregator threads: [`PyramidBuilder::fold_rows`] takes `&self` and
/// writes disjoint regions per source row.
pub struct PyramidBuilder {
    /// Tree depth of the finest leaves == number of pyramid levels.
    max_depth: u32,
    /// Per snapshot row (partition curve order): contribution, if the row
    /// is a leaf. Interior rows carry no authoritative data for the fold.
    targets: Vec<Option<RowTarget>>,
    /// `levels[ℓ - 1]` accumulates pyramid level `ℓ` (depth `max_depth−ℓ`).
    levels: Vec<LevelBuf>,
    /// Leaf rows folded so far; `finish` requires all of them.
    folded: AtomicU64,
    n_leaf_rows: u64,
}

impl PyramidBuilder {
    /// Set up accumulation buffers for `tree`'s pyramid. Rows are expected
    /// in the snapshot's row order (`part.curve`). A root-only tree has no
    /// pyramid ([`PyramidBuilder::is_empty`]).
    pub fn new(tree: &SpaceTree, part: &Partition) -> PyramidBuilder {
        let d_max = tree.max_depth();
        let mut levels: Vec<LevelBuf> = Vec::with_capacity(d_max as usize);
        for l in 1..=d_max {
            let depth = d_max - l;
            let mut locs: Vec<LocCode> = tree
                .nodes
                .iter()
                .filter(|n| n.depth() == depth)
                .map(|n| n.loc)
                .collect();
            locs.sort_by_key(|c| c.0);
            let row_of: HashMap<u32, usize> =
                locs.iter().enumerate().map(|(i, c)| (c.0, i)).collect();
            let direct: Vec<bool> = locs
                .iter()
                .map(|c| tree.node(tree.lookup(*c).unwrap()).is_leaf())
                .collect();
            let mut data = vec![0.0f32; locs.len() * ROW_ELEMS];
            let ptr = SendPtr::new(&mut data);
            levels.push(LevelBuf {
                locs,
                row_of,
                direct,
                data,
                ptr,
            });
        }
        let mut targets: Vec<Option<RowTarget>> = vec![None; tree.len()];
        let mut n_leaf_rows = 0u64;
        for (row, &idx) in part.curve.iter().enumerate() {
            let node = tree.node(idx);
            if d_max == 0 || !node.is_leaf() {
                continue;
            }
            n_leaf_rows += 1;
            let d = node.depth();
            targets[row] = Some(if d == d_max {
                RowTarget::Fold {
                    level_row: levels[0].row_of[&node.loc.parent().unwrap().0],
                    octant: node.loc.octant(),
                }
            } else {
                let level_ix = (d_max - d - 1) as usize;
                RowTarget::Direct {
                    level_ix,
                    level_row: levels[level_ix].row_of[&node.loc.0],
                }
            });
        }
        PyramidBuilder {
            max_depth: d_max,
            targets,
            levels,
            folded: AtomicU64::new(0),
            n_leaf_rows,
        }
    }

    /// True when the tree has no refinement — nothing to store.
    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    /// Number of pyramid levels (== the finest leaves' tree depth).
    pub fn n_levels(&self) -> u32 {
        self.max_depth
    }

    /// Leaf rows folded so far (metrics).
    pub fn rows_folded(&self) -> u64 {
        self.folded.load(Ordering::Relaxed)
    }

    /// Fold `data` — whole rows of the source dataset starting at row
    /// `row_start` — into the accumulation buffers. Called by the
    /// aggregator threads during the fill phase; safe for concurrent calls
    /// with *distinct* rows (each leaf row owns a disjoint cell region of
    /// its target grid). Interior rows are skipped: only leaves carry
    /// authoritative data.
    pub fn fold_rows(&self, row_start: u64, data: &[u8]) {
        let rb = ROW_BYTES as usize;
        debug_assert_eq!(data.len() % rb, 0);
        for (r, row) in data.chunks_exact(rb).enumerate() {
            let Some(target) = self
                .targets
                .get(row_start as usize + r)
                .copied()
                .flatten()
            else {
                continue;
            };
            let vals = codec::bytes_to_f32s(row);
            match target {
                RowTarget::Fold { level_row, octant } => {
                    // eight sibling leaves share this destination row (one
                    // octant each, possibly on different aggregator
                    // threads), so a whole-row `&mut` would alias across
                    // threads — store each cell through the raw pointer
                    let ptr = self.levels[0].ptr;
                    let base = level_row * ROW_ELEMS;
                    fold_octant_cells(&vals, octant, |at, val| {
                        // SAFETY: each leaf owns its octant's disjoint
                        // cells; `base + at` is in bounds of the level buf
                        unsafe { *ptr.base().add(base + at) = val }
                    });
                }
                RowTarget::Direct { level_ix, level_row } => {
                    // SAFETY: this leaf is the only writer of the row
                    let dst = unsafe {
                        self.levels[level_ix].ptr.slice(level_row * ROW_ELEMS, ROW_ELEMS)
                    };
                    dst.copy_from_slice(&vals);
                }
            }
            self.folded.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fold the interior levels (`ℓ−1 → ℓ` for `ℓ ≥ 2`) once every leaf
    /// row has passed through [`PyramidBuilder::fold_rows`]. Errors if the
    /// collective write did not cover every leaf — a partial pyramid would
    /// silently serve zeros.
    pub fn finish(&mut self) -> Result<()> {
        let folded = self.folded.load(Ordering::Relaxed);
        if folded < self.n_leaf_rows {
            bail!(
                "lod: pyramid fold incomplete ({folded} of {} leaf rows seen)",
                self.n_leaf_rows
            );
        }
        for li in 1..self.levels.len() {
            let (src_part, dst_part) = self.levels.split_at_mut(li);
            let src = &src_part[li - 1];
            let dst = &mut dst_part[0];
            // resolve every folded row's 8 children up front (all present
            // by construction: a stored grid is either a leaf copy or has
            // a fully-covered subtree below it)
            let mut jobs: Vec<(usize, [usize; 8])> = Vec::new();
            for row in 0..dst.locs.len() {
                if dst.direct[row] {
                    continue;
                }
                let mut kids = [0usize; 8];
                for (oct, kid) in kids.iter_mut().enumerate() {
                    let child = dst.locs[row].child(oct as u8);
                    *kid = *src.row_of.get(&child.0).ok_or_else(|| {
                        anyhow!("lod: level {} grid missing child {oct}", li + 1)
                    })?;
                }
                jobs.push((row, kids));
            }
            let dst_ptr = SendPtr::new(&mut dst.data);
            let src_data = &src.data;
            parallel_for(jobs.len(), |i| {
                let (row, kids) = jobs[i];
                // SAFETY: each job owns one whole destination row
                let out = unsafe { dst_ptr.slice(row * ROW_ELEMS, ROW_ELEMS) };
                for (oct, &crow) in kids.iter().enumerate() {
                    let s = &src_data[crow * ROW_ELEMS..(crow + 1) * ROW_ELEMS];
                    fold_octant(s, out, oct as u8);
                }
            });
        }
        Ok(())
    }

    /// Store the pyramid under `<ts_group>/lod`. Creates the level
    /// datasets on first write (chunked + compressed when the file format
    /// allows and `compress` asks for it); a steering rewrite of the same
    /// snapshot overwrites the rows in place, so the free-space manager
    /// recycles the superseded chunk extents like any other rewrite.
    pub fn write(
        &self,
        file: &mut H5File,
        ts_group: &str,
        compress: bool,
    ) -> Result<LodWriteReport> {
        let t0 = Instant::now();
        let mut report = LodWriteReport {
            levels: self.max_depth,
            raw_bytes: 0,
            stored_bytes: 0,
            write_seconds: 0.0,
        };
        if self.is_empty() {
            return Ok(report);
        }
        let group = format!("{ts_group}/{LOD_GROUP}");
        let chunked = compress && file.version() >= FORMAT_V2;
        {
            let g = file.ensure_group(&group);
            g.attrs
                .insert("levels".into(), Attr::I64(self.max_depth as i64));
            g.attrs
                .insert("source".into(), Attr::Str("current_cell_data".into()));
            g.attrs.insert("fold".into(), Attr::Str("mean".into()));
            g.attrs
                .insert("row_elems".into(), Attr::I64(ROW_ELEMS as i64));
        }
        for (li, lvl) in self.levels.iter().enumerate() {
            let l = li as u64 + 1;
            let n = lvl.locs.len() as u64;
            let cells_name = format!("level_{l}_cells");
            let ds = match file.dataset(&group, &cells_name) {
                Ok(ds) => {
                    if ds.shape[..] != [n, ROW_ELEMS as u64] {
                        bail!("lod: level {l} shape changed since the pyramid was created");
                    }
                    ds
                }
                Err(_) => {
                    let ds = if chunked {
                        file.create_dataset_chunked(
                            &group,
                            &cells_name,
                            Dtype::F32,
                            &[n, ROW_ELEMS as u64],
                            CHUNK_ROWS,
                            Codec::SHUFFLE_DELTA_LZ,
                        )?
                    } else {
                        file.create_dataset(
                            &group,
                            &cells_name,
                            Dtype::F32,
                            &[n, ROW_ELEMS as u64],
                        )?
                    };
                    let locs_ds = file.create_dataset(
                        &group,
                        &format!("level_{l}_locs"),
                        Dtype::U64,
                        &[n],
                    )?;
                    let raw: Vec<u64> = lvl.locs.iter().map(|c| c.0 as u64).collect();
                    file.write_rows(&locs_ds, 0, &codec::u64s_to_bytes(&raw))?;
                    ds
                }
            };
            file.write_rows(&ds, 0, &codec::f32s_to_bytes(&lvl.data))?;
            report.raw_bytes += n * ROW_BYTES;
            report.stored_bytes += file.dataset_stored_bytes(&ds)? + n * 8;
        }
        report.write_seconds = t0.elapsed().as_secs_f64();
        Ok(report)
    }

    /// In-memory view of one accumulated level (tests / diagnostics):
    /// `(locs, cell data)` of pyramid level `level` (1-based).
    pub fn level_data(&self, level: u32) -> Option<(&[LocCode], &[f32])> {
        let ix = (level as usize).checked_sub(1)?;
        self.levels.get(ix).map(|l| (&l.locs[..], &l.data[..]))
    }
}

/// The fold's arithmetic core: compute every destination cell of `octant`
/// and hand `(index within the destination row, value)` to `write` — the
/// one place the downsampling index math lives, shared by
/// [`fold_octant`]'s slice path and the fill phase's per-cell raw-pointer
/// path (where a whole-row `&mut` would alias across threads).
fn fold_octant_cells(src: &[f32], octant: u8, mut write: impl FnMut(usize, f32)) {
    debug_assert_eq!(src.len(), NVAR * DGRID_CELLS);
    let half = DGRID_N / 2;
    let bi = ((octant >> 2) & 1) as usize * half;
    let bj = ((octant >> 1) & 1) as usize * half;
    let bk = (octant & 1) as usize * half;
    for (v, s) in src.chunks_exact(DGRID_CELLS).enumerate() {
        for i in 0..half {
            for j in 0..half {
                for k in 0..half {
                    let mut sum = 0.0f32;
                    for di in 0..2 {
                        for dj in 0..2 {
                            for dk in 0..2 {
                                sum += s[iidx(2 * i + di, 2 * j + dj, 2 * k + dk)];
                            }
                        }
                    }
                    write(
                        v * DGRID_CELLS + iidx(bi + i, bj + j, bk + k),
                        sum * 0.125,
                    );
                }
            }
        }
    }
}

/// Mean-fold one source grid's interior (all [`NVAR`] variables, 16³ each)
/// 2× down into `octant` of `dst` — used by the builder's interior-level
/// fold (which exclusively owns `dst`) and the property tests. `octant`
/// uses the location-code bit order (x|y|z).
pub fn fold_octant(src: &[f32], dst: &mut [f32], octant: u8) {
    debug_assert_eq!(dst.len(), ROW_ELEMS);
    fold_octant_cells(src, octant, |at, val| dst[at] = val);
}

// ---------------------------------------------------------------------------
// read side
// ---------------------------------------------------------------------------

/// One stored pyramid level, opened for reading.
pub struct LodLevel {
    /// 1-based pyramid level (1 = one fold below full resolution).
    pub level: u32,
    /// Tree depth of this level's grids (`levels − level`).
    pub depth: u32,
    /// Morton-ordered location codes; row `i` holds the grid at `locs[i]`.
    pub locs: Vec<LocCode>,
    row_of: HashMap<u32, u64>,
    /// The `level_<ℓ>_cells` dataset.
    pub cells: Dataset,
}

impl LodLevel {
    /// Row holding the grid at `loc`, if stored (an adaptive tree stores
    /// nothing finer than its covering coarse leaf).
    pub fn row_of(&self, loc: LocCode) -> Option<u64> {
        self.row_of.get(&loc.0).copied()
    }

    /// Read and decode one grid row.
    pub fn read_row(&self, file: &H5File, row: u64) -> Result<Vec<f32>> {
        Ok(codec::bytes_to_f32s(&file.read_rows(&self.cells, row, 1)?))
    }
}

/// The pyramid of one snapshot, opened for budget-aware reads.
///
/// [`LodIndex::open`] reads every `level_<ℓ>_locs` dataset and rebuilds
/// the row maps — pay that once per snapshot, not per query: the
/// documented hot-path consumer is the `crate::window::SnapshotReader`
/// session, which holds one `LodIndex` for its whole lifetime.
pub struct LodIndex {
    /// Levels 1..=n in order; `levels[0]` is the finest stored level.
    pub levels: Vec<LodLevel>,
    /// Bytes read to load the location indexes (part of a query's cost).
    pub index_bytes: u64,
}

impl LodIndex {
    /// Open the pyramid of `ts_group`, or `Ok(None)` for a pyramid-less
    /// snapshot (pre-LOD files and `SnapshotOptions { lod: false, .. }`).
    pub fn open(file: &H5File, ts_group: &str) -> Result<Option<LodIndex>> {
        let group = format!("{ts_group}/{LOD_GROUP}");
        let Ok(g) = file.group(&group) else {
            return Ok(None);
        };
        let n_levels = match g.attrs.get("levels") {
            Some(Attr::I64(v)) if *v > 0 => *v as u32,
            _ => return Ok(None),
        };
        let mut levels = Vec::with_capacity(n_levels as usize);
        let mut index_bytes = 0u64;
        for l in 1..=n_levels {
            let cells = file.dataset(&group, &format!("level_{l}_cells"))?;
            let locs_ds = file.dataset(&group, &format!("level_{l}_locs"))?;
            let raw = file.read_all_u64(&locs_ds)?;
            index_bytes += raw.len() as u64 * 8;
            let locs: Vec<LocCode> = raw.into_iter().map(|v| LocCode(v as u32)).collect();
            let row_of = locs
                .iter()
                .enumerate()
                .map(|(i, c)| (c.0, i as u64))
                .collect();
            levels.push(LodLevel {
                level: l,
                depth: n_levels - l,
                locs,
                row_of,
                cells,
            });
        }
        Ok(Some(LodIndex { levels, index_bytes }))
    }

    /// Coarsest level number (== pyramid levels == finest-leaf depth).
    pub fn max_level(&self) -> u32 {
        self.levels.len() as u32
    }

    /// Level `l` (1-based; `None` for 0 — that is the full-res source).
    pub fn level(&self, l: u32) -> Option<&LodLevel> {
        self.levels.get((l as usize).checked_sub(1)?)
    }
}

// ---------------------------------------------------------------------------
// uniform-grid geometry helpers (selection without touching the topology
// datasets — a pyramid level is a complete 2^depth-per-axis tiling)
// ---------------------------------------------------------------------------

/// Bounding box of the depth-`depth` grid at integer coords `(i, j, k)`.
pub fn grid_bbox(domain: &BBox, depth: u32, i: u32, j: u32, k: u32) -> BBox {
    let side = (1u64 << depth) as f64;
    let c = [i as f64, j as f64, k as f64];
    let mut b = BBox::default();
    for a in 0..3 {
        let w = domain.extent(a) / side;
        b.min[a] = domain.min[a] + c[a] * w;
        b.max[a] = domain.min[a] + (c[a] + 1.0) * w;
    }
    b
}

/// Half-open integer coordinate ranges, per axis, of the depth-`depth`
/// grids whose boxes intersect `window` (same strict-inequality semantics
/// as [`BBox::intersects`]). Empty ranges when the window misses the
/// domain.
pub fn coord_range(domain: &BBox, depth: u32, window: &BBox) -> [(u32, u32); 3] {
    let side = 1u64 << depth;
    let mut out = [(0u32, 0u32); 3];
    for a in 0..3 {
        let w = domain.extent(a) / side as f64;
        let lo = ((window.min[a] - domain.min[a]) / w).floor().max(0.0) as u64;
        let hi = ((window.max[a] - domain.min[a]) / w).ceil() as u64;
        let lo = lo.min(side);
        let hi = hi.min(side).max(lo);
        out[a] = (lo as u32, hi as u32);
    }
    out
}

/// Number of depth-`depth` grids intersecting `window` — O(1) arithmetic,
/// the budget-fit estimate of the level selector. (For adaptive trees this
/// counts as if the tiling were complete, an upper bound on what a query
/// actually reads, so a level chosen by it never bursts the budget.)
pub fn intersect_count(domain: &BBox, depth: u32, window: &BBox) -> u64 {
    coord_range(domain, depth, window)
        .iter()
        .map(|&(lo, hi)| (hi - lo) as u64)
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::sfc;

    fn tree_and_part(depth: u32, ranks: u32) -> (SpaceTree, Partition) {
        let mut tree = SpaceTree::full(BBox::unit(), depth);
        let part = sfc::partition(&mut tree, ranks);
        (tree, part)
    }

    /// Row bytes of the source dataset.
    const RB: usize = ROW_BYTES as usize;

    /// A snapshot-row buffer where every cell of every var of row `r`
    /// holds `value_of(r)`.
    fn rows_with(n: usize, value_of: impl Fn(usize) -> f32) -> Vec<u8> {
        let mut out = Vec::with_capacity(n * RB);
        for r in 0..n {
            let row = [value_of(r); ROW_ELEMS];
            out.extend_from_slice(&codec::f32s_to_bytes(&row));
        }
        out
    }

    #[test]
    fn root_only_tree_has_no_pyramid() {
        let (tree, part) = tree_and_part(0, 1);
        let b = PyramidBuilder::new(&tree, &part);
        assert!(b.is_empty());
        assert_eq!(b.n_levels(), 0);
    }

    #[test]
    fn uniform_leaves_fold_to_uniform_levels() {
        let (tree, part) = tree_and_part(2, 3);
        let mut b = PyramidBuilder::new(&tree, &part);
        assert_eq!(b.n_levels(), 2);
        // every leaf holds 7.0; interior rows hold garbage the fold must
        // ignore (here: 0.0 via the constant, distinguishable anyway)
        let data = rows_with(tree.len(), |r| {
            if tree.node(part.curve[r]).is_leaf() {
                7.0
            } else {
                -1.0
            }
        });
        b.fold_rows(0, &data);
        b.finish().unwrap();
        for level in [1u32, 2] {
            let (locs, cells) = b.level_data(level).unwrap();
            assert_eq!(locs.len(), if level == 1 { 8 } else { 1 });
            assert!(
                cells.iter().all(|&x| x == 7.0),
                "level {level} not uniform"
            );
        }
    }

    #[test]
    fn fold_octant_places_mean_in_the_right_corner() {
        let mut src = vec![0.0f32; ROW_ELEMS];
        // var 1, cell (0,0,0..2): values 8 and 16 → the 2×2×2 block mean is
        // (8 + 16) / 8 = 3.0
        src[DGRID_CELLS + iidx(0, 0, 0)] = 8.0;
        src[DGRID_CELLS + iidx(0, 0, 1)] = 16.0;
        let mut dst = vec![0.0f32; ROW_ELEMS];
        fold_octant(&src, &mut dst, 0b101); // +x, −y, +z octant
        let expect_at = iidx(8, 0, 8);
        assert_eq!(dst[DGRID_CELLS + expect_at], 3.0);
        // nothing else written in that var
        let written = dst[DGRID_CELLS..2 * DGRID_CELLS]
            .iter()
            .filter(|&&x| x != 0.0)
            .count();
        assert_eq!(written, 1);
        // other vars untouched
        assert!(dst[..DGRID_CELLS].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn finish_requires_full_leaf_coverage() {
        let (tree, part) = tree_and_part(1, 2);
        let mut b = PyramidBuilder::new(&tree, &part);
        // only the first 3 rows folded — 8 leaves exist
        b.fold_rows(0, &rows_with(3, |_| 1.0));
        assert!(b.finish().is_err());
    }

    #[test]
    fn adaptive_coarse_leaf_is_copied_verbatim() {
        // refine only child 0 of the root: leaves at depth 1 (7 of them)
        // and depth 2 (8); the depth-1 leaves land verbatim in level 1
        let mut tree = SpaceTree::root_only(BBox::unit());
        tree.refine(0);
        let c0 = tree.lookup(LocCode::ROOT.child(0)).unwrap();
        tree.refine(c0);
        let part = sfc::partition(&mut tree, 2);
        let mut b = PyramidBuilder::new(&tree, &part);
        assert_eq!(b.n_levels(), 2);
        let data = rows_with(tree.len(), |r| {
            let n = tree.node(part.curve[r]);
            if !n.is_leaf() {
                return -1.0;
            }
            if n.depth() == 1 {
                5.0 // coarse leaves
            } else {
                9.0 // fine leaves under c0
            }
        });
        b.fold_rows(0, &data);
        b.finish().unwrap();
        let (locs1, cells1) = b.level_data(1).unwrap();
        assert_eq!(locs1.len(), 8);
        for (i, loc) in locs1.iter().enumerate() {
            let want = if *loc == LocCode::ROOT.child(0) {
                9.0 // folded from the uniform fine leaves
            } else {
                5.0 // verbatim coarse-leaf copy
            };
            let row = &cells1[i * ROW_ELEMS..(i + 1) * ROW_ELEMS];
            assert!(row.iter().all(|&x| x == want), "level-1 grid {i}");
        }
        // the root is octant-structured: cells folded from c0's grid hold
        // 9.0, the rest 5.0 (octant 0 is the −x,−y,−z corner)
        let (_, cells2) = b.level_data(2).unwrap();
        assert_eq!(cells2[iidx(0, 0, 0)], 9.0);
        assert_eq!(cells2[iidx(7, 7, 7)], 9.0);
        assert_eq!(cells2[iidx(8, 8, 8)], 5.0);
        assert_eq!(cells2[iidx(0, 0, 8)], 5.0);
    }

    #[test]
    fn concurrent_fold_matches_serial() {
        let (tree, part) = tree_and_part(2, 4);
        let n = tree.len();
        let data = rows_with(n, |r| (r as f32 * 0.37).sin());
        let mut serial = PyramidBuilder::new(&tree, &part);
        serial.fold_rows(0, &data);
        serial.finish().unwrap();
        let mut threaded = PyramidBuilder::new(&tree, &part);
        std::thread::scope(|s| {
            let b = &threaded;
            let d = &data;
            for t in 0..4usize {
                s.spawn(move || {
                    // interleaved row blocks, like aggregator chunk jobs
                    let mut r = t;
                    while r < n {
                        b.fold_rows(r as u64, &d[r * RB..(r + 1) * RB]);
                        r += 4;
                    }
                });
            }
        });
        threaded.finish().unwrap();
        for level in 1..=2u32 {
            let (_, a) = serial.level_data(level).unwrap();
            let (_, b) = threaded.level_data(level).unwrap();
            assert_eq!(a, b, "level {level}");
        }
    }

    #[test]
    fn coord_range_matches_bbox_intersection() {
        let domain = BBox::unit();
        for depth in 0..4u32 {
            let side = 1u32 << depth;
            for window in [
                BBox::unit(),
                BBox {
                    min: [0.0; 3],
                    max: [0.5, 1.0, 1.0],
                },
                BBox {
                    min: [0.24, 0.24, 0.24],
                    max: [0.26, 0.76, 0.26],
                },
                BBox {
                    min: [2.0; 3],
                    max: [3.0; 3],
                }, // misses the domain
            ] {
                let [ri, rj, rk] = coord_range(&domain, depth, &window);
                let mut count = 0u64;
                for i in 0..side {
                    for j in 0..side {
                        for k in 0..side {
                            let hit = grid_bbox(&domain, depth, i, j, k).intersects(&window);
                            let in_range = (ri.0..ri.1).contains(&i)
                                && (rj.0..rj.1).contains(&j)
                                && (rk.0..rk.1).contains(&k);
                            assert_eq!(hit, in_range, "depth {depth} ({i},{j},{k})");
                            count += hit as u64;
                        }
                    }
                }
                assert_eq!(count, intersect_count(&domain, depth, &window));
            }
        }
    }

    #[test]
    fn write_and_reopen_roundtrip() {
        let p = std::env::temp_dir().join(format!("lod_test_{}.h5", std::process::id()));
        let (tree, part) = tree_and_part(2, 3);
        let data = rows_with(tree.len(), |r| {
            if tree.node(part.curve[r]).is_leaf() {
                3.5
            } else {
                -1.0
            }
        });
        {
            let mut f = H5File::create(&p, 1).unwrap();
            f.ensure_group("/simulation/t=0.000000");
            let mut b = PyramidBuilder::new(&tree, &part);
            b.fold_rows(0, &data);
            b.finish().unwrap();
            let rep = b.write(&mut f, "/simulation/t=0.000000", true).unwrap();
            assert_eq!(rep.levels, 2);
            assert_eq!(rep.raw_bytes, 9 * ROW_BYTES);
            assert!(rep.stored_bytes > 0);
            f.commit().unwrap();
        }
        let f = H5File::open(&p).unwrap();
        let idx = LodIndex::open(&f, "/simulation/t=0.000000")
            .unwrap()
            .expect("pyramid missing after reopen");
        assert_eq!(idx.max_level(), 2);
        let l2 = idx.level(2).unwrap();
        assert_eq!(l2.depth, 0);
        assert_eq!(l2.locs[..], [LocCode::ROOT]);
        let row = l2.row_of(LocCode::ROOT).unwrap();
        let cells = l2.read_row(&f, row).unwrap();
        assert!(cells.iter().all(|&x| x == 3.5));
        assert!(idx.level(0).is_none());
        // a snapshot group without a pyramid reads back as None
        assert!(LodIndex::open(&f, "/simulation").unwrap().is_none());
        std::fs::remove_file(&p).ok();
    }
}
