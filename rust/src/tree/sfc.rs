//! Space-filling-curve partitioning (paper §2.2).
//!
//! *"The assignment per se follows a space-filling Lebesgue curve that has
//! proven to preserve neighbouring relations, thus reducing the necessary
//! communication overhead."*
//!
//! The Lebesgue curve is the Z-order / Morton curve. For the adaptive tree
//! we use its natural generalisation: a depth-first pre-order traversal with
//! Z-ordered children (exactly [`SpaceTree::dfs_order`]), which reduces to
//! plain Morton order on a single fully-refined level. Ranks receive
//! contiguous, load-balanced chunks of this sequence; contiguity along the
//! curve is what preserves spatial locality. The root is first on the curve
//! and therefore always lands on rank 0 — the paper's invariant that the
//! root grid is row 0 of every checkpoint dataset.

use crate::tree::SpaceTree;

/// Result of a partition: per-node rank/local assignment is written into the
/// tree; the summary is returned for diagnostics and the I/O layer.
#[derive(Clone, Debug)]
pub struct Partition {
    pub n_ranks: u32,
    /// Number of grids per rank.
    pub counts: Vec<u32>,
    /// Arena indices in curve order (row order of checkpoint datasets).
    pub curve: Vec<u32>,
}

impl Partition {
    /// Prefix sum of `counts`: global row index where each rank's block of
    /// dataset rows starts (the paper computes this with an MPI prefix
    /// reduction, §3.2).
    pub fn row_offsets(&self) -> Vec<u64> {
        let mut off = Vec::with_capacity(self.counts.len() + 1);
        let mut acc = 0u64;
        for &c in &self.counts {
            off.push(acc);
            acc += c as u64;
        }
        off.push(acc);
        off
    }
}

/// Assign every l-grid (interior nodes included — their d-grids hold the
/// restricted data) to one of `n_ranks` ranks along the Lebesgue curve,
/// writing `rank` and `local` into the tree. Balanced to ±1 grid.
pub fn partition(tree: &mut SpaceTree, n_ranks: u32) -> Partition {
    assert!(n_ranks >= 1);
    let curve = tree.dfs_order();
    let n = curve.len() as u32;
    let base = n / n_ranks;
    let rem = n % n_ranks;
    let mut counts = vec![0u32; n_ranks as usize];
    let mut pos = 0u32;
    for r in 0..n_ranks {
        let take = base + if r < rem { 1 } else { 0 };
        let mut local = 0u32;
        for _ in 0..take {
            let idx = curve[pos as usize];
            let node = &mut tree.nodes[idx as usize];
            node.rank = r;
            node.local = local;
            local += 1;
            pos += 1;
        }
        counts[r as usize] = take;
    }
    Partition {
        n_ranks,
        counts,
        curve,
    }
}

/// Morton key of a leaf at `(i, j, k)` on level `depth` — exposed for tests
/// and for the VPIC workload generator.
pub fn morton_key(depth: u32, i: u32, j: u32, k: u32) -> u64 {
    let mut key = 0u64;
    for lvl in (0..depth).rev() {
        let oct = (((i >> lvl) & 1) << 2) | (((j >> lvl) & 1) << 1) | ((k >> lvl) & 1);
        key = (key << 3) | oct as u64;
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::uid::LocCode;
    use crate::tree::BBox;

    #[test]
    fn partition_is_balanced() {
        let mut t = SpaceTree::full(BBox::unit(), 2); // 73 nodes
        let p = partition(&mut t, 8);
        assert_eq!(p.counts.iter().sum::<u32>(), 73);
        let (min, max) = (
            *p.counts.iter().min().unwrap(),
            *p.counts.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "{:?}", p.counts);
    }

    #[test]
    fn root_is_rank0_row0() {
        let mut t = SpaceTree::full(BBox::unit(), 3);
        let p = partition(&mut t, 17);
        assert_eq!(t.node(0).rank, 0);
        assert_eq!(t.node(0).local, 0);
        assert_eq!(p.curve[0], 0);
    }

    #[test]
    fn ranks_are_contiguous_on_curve() {
        let mut t = SpaceTree::full(BBox::unit(), 2);
        let p = partition(&mut t, 5);
        let ranks: Vec<u32> = p.curve.iter().map(|&i| t.node(i).rank).collect();
        // non-decreasing along the curve
        assert!(ranks.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn locals_are_sequential_within_rank() {
        let mut t = SpaceTree::full(BBox::unit(), 2);
        let p = partition(&mut t, 4);
        for r in 0..4 {
            let locals: Vec<u32> = p
                .curve
                .iter()
                .filter(|&&i| t.node(i).rank == r)
                .map(|&i| t.node(i).local)
                .collect();
            let expect: Vec<u32> = (0..locals.len() as u32).collect();
            assert_eq!(locals, expect);
        }
    }

    #[test]
    fn row_offsets_prefix_sum() {
        let p = Partition {
            n_ranks: 3,
            counts: vec![4, 2, 5],
            curve: vec![],
        };
        assert_eq!(p.row_offsets(), vec![0, 4, 6, 11]);
    }

    #[test]
    fn morton_key_locality() {
        // consecutive keys differ in one coordinate step at the finest level
        let a = morton_key(3, 0, 0, 0);
        let b = morton_key(3, 0, 0, 1);
        assert_eq!(b, a + 1);
        // key ordering equals LocCode ordering within a level
        let l1 = LocCode::from_coords(3, 1, 2, 3).unwrap();
        let l2 = LocCode::from_coords(3, 1, 2, 4).unwrap();
        assert_eq!(
            morton_key(3, 1, 2, 3) < morton_key(3, 1, 2, 4),
            l1.0 < l2.0
        );
    }

    #[test]
    fn partition_single_rank_takes_all() {
        let mut t = SpaceTree::full(BBox::unit(), 1);
        let p = partition(&mut t, 1);
        assert_eq!(p.counts, vec![9]);
        assert!(t.nodes.iter().all(|n| n.rank == 0));
    }

    #[test]
    fn more_ranks_than_grids() {
        let mut t = SpaceTree::root_only(BBox::unit());
        let p = partition(&mut t, 4);
        assert_eq!(p.counts, vec![1, 0, 0, 0]);
    }
}
