//! The hierarchical **space-tree** data structure (paper §2.2).
//!
//! Starting from a single root cell at depth 0, each cell is subdivided into
//! `2×2×2` children until a predefined maximum depth (the paper's general
//! `r_x × r_y × r_z` refinement with the bisection setting used throughout
//! its evaluation). The hierarchy of *logical grids* (l-grids) carries the
//! topology; every l-grid node links to a computational *data grid*
//! ([`dgrid::DGrid`]) of `16³` cells — including interior nodes, whose
//! d-grids hold the averaged (restricted) values that the bottom-up
//! communication step maintains and that the sliding window reads for
//! coarse levels of detail.
//!
//! Adaptive subdivision is supported with an enforced 2:1 level balance
//! between face neighbours so that the ghost-layer exchange only ever deals
//! with one level of difference — matching the paper's three-phase
//! communication schema.

pub mod dgrid;
pub mod sfc;
pub mod uid;

use std::collections::HashMap;


use uid::{LocCode, Uid, MAX_DEPTH};

/// Axis-aligned physical bounding box (the `bounding box` dataset row).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct BBox {
    pub min: [f64; 3],
    pub max: [f64; 3],
}

impl BBox {
    pub fn unit() -> BBox {
        BBox {
            min: [0.0; 3],
            max: [1.0; 3],
        }
    }

    pub fn extent(&self, axis: usize) -> f64 {
        self.max[axis] - self.min[axis]
    }

    pub fn intersects(&self, other: &BBox) -> bool {
        (0..3).all(|a| self.min[a] < other.max[a] && self.max[a] > other.min[a])
    }

    pub fn contains_point(&self, p: [f64; 3]) -> bool {
        (0..3).all(|a| p[a] >= self.min[a] && p[a] < self.max[a])
    }

    /// Bounding box of child `octant` under 2×2×2 bisection.
    pub fn child(&self, octant: u8) -> BBox {
        let mid = [
            0.5 * (self.min[0] + self.max[0]),
            0.5 * (self.min[1] + self.max[1]),
            0.5 * (self.min[2] + self.max[2]),
        ];
        let mut min = self.min;
        let mut max = mid;
        for a in 0..3 {
            if (octant >> (2 - a)) & 1 == 1 {
                min[a] = mid[a];
                max[a] = self.max[a];
            }
        }
        BBox { min, max }
    }
}

/// One l-grid node in the arena.
#[derive(Clone, Debug)]
pub struct LGrid {
    pub loc: LocCode,
    pub bbox: BBox,
    /// Arena indices of the eight children (empty for leaves).
    pub children: Vec<u32>,
    /// Arena index of the parent (`u32::MAX` for the root).
    pub parent: u32,
    /// Owning MPI rank — assigned by [`sfc::partition`].
    pub rank: u32,
    /// Rank-local sequential id — assigned with the partition.
    pub local: u32,
}

impl LGrid {
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }

    pub fn uid(&self) -> Uid {
        Uid::new(self.rank, self.local, self.loc)
    }

    pub fn depth(&self) -> u32 {
        self.loc.depth()
    }
}

/// The space-tree: an arena of l-grids plus a location-code index.
///
/// d-grid payloads are stored separately (see [`crate::coordinator`]) so the
/// topology can be shipped to the neighbourhood server without field data.
#[derive(Clone, Debug, Default)]
pub struct SpaceTree {
    pub nodes: Vec<LGrid>,
    index: HashMap<LocCode, u32>,
    pub domain: BBox,
}

impl SpaceTree {
    /// A tree with only the root node.
    pub fn root_only(domain: BBox) -> SpaceTree {
        let mut t = SpaceTree {
            nodes: vec![LGrid {
                loc: LocCode::ROOT,
                bbox: domain,
                children: Vec::new(),
                parent: u32::MAX,
                rank: 0,
                local: 0,
            }],
            index: HashMap::new(),
            domain,
        };
        t.index.insert(LocCode::ROOT, 0);
        t
    }

    /// Fully refined tree of `depth` levels (every node subdivided).
    pub fn full(domain: BBox, depth: u32) -> SpaceTree {
        let mut t = SpaceTree::root_only(domain);
        for d in 0..depth {
            let at_depth: Vec<u32> = t
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| n.depth() == d)
                .map(|(i, _)| i as u32)
                .collect();
            for idx in at_depth {
                t.refine(idx);
            }
        }
        t
    }

    /// Adaptively refined tree: subdivide every node for which `pred`
    /// returns true (evaluated coarsest-first), then restore 2:1 balance.
    pub fn adaptive(
        domain: BBox,
        max_depth: u32,
        pred: &dyn Fn(&BBox, u32) -> bool,
    ) -> SpaceTree {
        let mut t = SpaceTree::root_only(domain);
        for d in 0..max_depth {
            let at_depth: Vec<u32> = t
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| n.depth() == d && n.is_leaf())
                .map(|(i, _)| i as u32)
                .collect();
            for idx in at_depth {
                let n = &t.nodes[idx as usize];
                if pred(&n.bbox, d) {
                    t.refine(idx);
                }
            }
        }
        t.balance();
        t
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, idx: u32) -> &LGrid {
        &self.nodes[idx as usize]
    }

    pub fn lookup(&self, loc: LocCode) -> Option<u32> {
        self.index.get(&loc).copied()
    }

    /// Rebuild the location index (needed after deserialisation).
    pub fn rebuild_index(&mut self) {
        self.index = self
            .nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (n.loc, i as u32))
            .collect();
    }

    /// Subdivide node `idx` into 8 children. No-op if already refined.
    pub fn refine(&mut self, idx: u32) {
        if !self.nodes[idx as usize].is_leaf() {
            return;
        }
        let (loc, bbox) = {
            let n = &self.nodes[idx as usize];
            (n.loc, n.bbox)
        };
        assert!(
            loc.depth() < MAX_DEPTH,
            "refinement beyond MAX_DEPTH={MAX_DEPTH}"
        );
        let mut children = Vec::with_capacity(8);
        for oct in 0..8u8 {
            let child_idx = self.nodes.len() as u32;
            let cl = loc.child(oct);
            self.nodes.push(LGrid {
                loc: cl,
                bbox: bbox.child(oct),
                children: Vec::new(),
                parent: idx,
                rank: 0,
                local: 0,
            });
            self.index.insert(cl, child_idx);
            children.push(child_idx);
        }
        self.nodes[idx as usize].children = children;
    }

    /// Remove the children of `idx` (coarsening; used by steering). Children
    /// must themselves be leaves. Returns false if the node was a leaf or
    /// has non-leaf children.
    pub fn coarsen(&mut self, idx: u32) -> bool {
        let children = self.nodes[idx as usize].children.clone();
        if children.is_empty() || children.iter().any(|&c| !self.nodes[c as usize].is_leaf())
        {
            return false;
        }
        // Arena compaction: mark-and-rebuild (coarsening is rare — steering
        // only — so simplicity beats in-place trickery).
        let drop: std::collections::HashSet<u32> = children.into_iter().collect();
        let mut remap = vec![u32::MAX; self.nodes.len()];
        let mut kept = Vec::with_capacity(self.nodes.len() - 8);
        for (i, n) in self.nodes.iter().enumerate() {
            if !drop.contains(&(i as u32)) {
                remap[i] = kept.len() as u32;
                kept.push(n.clone());
            }
        }
        for n in &mut kept {
            if n.parent != u32::MAX {
                n.parent = remap[n.parent as usize];
            }
            n.children = n
                .children
                .iter()
                .filter(|c| remap[**c as usize] != u32::MAX)
                .map(|c| remap[*c as usize])
                .collect();
        }
        self.nodes = kept;
        self.rebuild_index();
        true
    }

    /// Enforce 2:1 balance between face-adjacent leaves: any leaf whose
    /// face neighbour is refined ≥ 2 levels deeper gets refined too.
    pub fn balance(&mut self) {
        loop {
            let mut to_refine = Vec::new();
            for (i, n) in self.nodes.iter().enumerate() {
                if !n.is_leaf() {
                    continue;
                }
                let d = n.depth();
                let (ci, cj, ck) = n.loc.coords();
                for (axis, dir) in [(0, -1i64), (0, 1), (1, -1), (1, 1), (2, -1), (2, 1)] {
                    let mut c = [ci as i64, cj as i64, ck as i64];
                    c[axis] += dir;
                    let side = 1i64 << d;
                    if c[axis] < 0 || c[axis] >= side {
                        continue;
                    }
                    if let Some(loc) =
                        LocCode::from_coords(d, c[0] as u32, c[1] as u32, c[2] as u32)
                    {
                        if let Some(nb) = self.lookup(loc) {
                            // neighbour exists at same level: refined ≥2 deeper?
                            if self.has_grandchildren(nb) {
                                to_refine.push(i as u32);
                                break;
                            }
                        }
                    }
                }
            }
            if to_refine.is_empty() {
                break;
            }
            for idx in to_refine {
                self.refine(idx);
            }
        }
    }

    fn has_grandchildren(&self, idx: u32) -> bool {
        self.nodes[idx as usize]
            .children
            .iter()
            .any(|&c| !self.nodes[c as usize].is_leaf())
    }

    /// Leaf count.
    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Total interior cells across all *leaf* d-grids (the domain resolution
    /// the paper quotes, e.g. depth 6 → 1024³ ≈ 1.07e9 cells when full).
    pub fn n_leaf_cells(&self) -> u64 {
        self.n_leaves() as u64 * crate::DGRID_CELLS as u64
    }

    /// Grid spacing (cell edge length) of a node at `depth`, assuming a
    /// cubic domain.
    pub fn h_at_depth(&self, depth: u32) -> f64 {
        self.domain.extent(0) / ((1u64 << depth) as f64 * crate::DGRID_N as f64)
    }

    /// Indices of all nodes at `depth`, in arena order.
    pub fn nodes_at_depth(&self, depth: u32) -> Vec<u32> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.depth() == depth)
            .map(|(i, _)| i as u32)
            .collect()
    }

    pub fn max_depth(&self) -> u32 {
        self.nodes.iter().map(|n| n.depth()).max().unwrap_or(0)
    }

    /// Depth-first pre-order traversal with Z-ordered children — the
    /// Lebesgue curve ordering used for partitioning and for the row order
    /// inside the checkpoint datasets.
    pub fn dfs_order(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.nodes.len());
        let mut stack = vec![0u32];
        while let Some(idx) = stack.pop() {
            out.push(idx);
            let n = &self.nodes[idx as usize];
            // push in reverse so children pop in Z-order
            for &c in n.children.iter().rev() {
                stack.push(c);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_tree_node_counts() {
        // depth 2: 1 + 8 + 64
        let t = SpaceTree::full(BBox::unit(), 2);
        assert_eq!(t.len(), 73);
        assert_eq!(t.n_leaves(), 64);
        assert_eq!(t.max_depth(), 2);
    }

    #[test]
    fn full_tree_leaf_cells_match_resolution() {
        // depth 2 → (16·2²)³ = 64³ cells
        let t = SpaceTree::full(BBox::unit(), 2);
        assert_eq!(t.n_leaf_cells(), 64 * 64 * 64);
    }

    #[test]
    fn bbox_children_tile_parent() {
        let b = BBox {
            min: [0.0, 1.0, 2.0],
            max: [4.0, 5.0, 6.0],
        };
        let mut vol = 0.0;
        for oct in 0..8 {
            let c = b.child(oct);
            vol += (0..3).map(|a| c.extent(a)).product::<f64>();
            for a in 0..3 {
                assert!(c.min[a] >= b.min[a] && c.max[a] <= b.max[a]);
            }
        }
        let parent_vol: f64 = (0..3).map(|a| b.extent(a)).product();
        assert!((vol - parent_vol).abs() < 1e-12);
    }

    #[test]
    fn child_octant_orientation_matches_loccode() {
        // octant bit layout is x|y|z in both BBox::child and LocCode
        let b = BBox::unit();
        let c = b.child(0b100); // +x half
        assert!(c.min[0] == 0.5 && c.min[1] == 0.0 && c.min[2] == 0.0);
        let t = SpaceTree::full(BBox::unit(), 1);
        let idx = t.lookup(LocCode::ROOT.child(0b100)).unwrap();
        assert_eq!(t.node(idx).bbox, c);
    }

    #[test]
    fn lookup_after_refine() {
        let mut t = SpaceTree::root_only(BBox::unit());
        t.refine(0);
        let c = LocCode::ROOT.child(3);
        let idx = t.lookup(c).unwrap();
        assert_eq!(t.node(idx).loc, c);
        assert_eq!(t.node(idx).parent, 0);
    }

    #[test]
    fn adaptive_refinement_refines_region_of_interest() {
        // refine only around the corner near the origin
        let t = SpaceTree::adaptive(BBox::unit(), 3, &|b, _| {
            b.contains_point([0.01, 0.01, 0.01]) || b.min == [0.0; 3]
        });
        assert!(t.max_depth() == 3);
        assert!(t.len() < SpaceTree::full(BBox::unit(), 3).len());
        // the far corner must stay coarse
        let far = LocCode::from_coords(3, 7, 7, 7).unwrap();
        assert!(t.lookup(far).is_none());
    }

    #[test]
    fn balance_limits_level_jump_to_one() {
        let t = SpaceTree::adaptive(BBox::unit(), 4, &|b, _| {
            b.contains_point([0.01, 0.01, 0.01])
        });
        // check every leaf against its face neighbours
        for n in t.nodes.iter().filter(|n| n.is_leaf()) {
            let d = n.depth();
            let (i, j, k) = n.loc.coords();
            for (axis, dir) in [(0, -1i64), (0, 1), (1, -1), (1, 1), (2, -1), (2, 1)] {
                let mut c = [i as i64, j as i64, k as i64];
                c[axis] += dir;
                if c[axis] < 0 || c[axis] >= 1 << d {
                    continue;
                }
                if let Some(loc) = LocCode::from_coords(d, c[0] as u32, c[1] as u32, c[2] as u32)
                {
                    if let Some(nb) = t.lookup(loc) {
                        for &ch in &t.node(nb).children {
                            assert!(
                                t.node(ch).is_leaf(),
                                "2:1 balance violated at {:?}",
                                n.loc
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn coarsen_removes_children() {
        let mut t = SpaceTree::full(BBox::unit(), 1);
        assert_eq!(t.len(), 9);
        assert!(t.coarsen(0));
        assert_eq!(t.len(), 1);
        assert!(t.node(0).is_leaf());
        assert!(!t.coarsen(0)); // already a leaf
    }

    #[test]
    fn coarsen_refuses_nonleaf_children() {
        let mut t = SpaceTree::full(BBox::unit(), 2);
        assert!(!t.coarsen(0));
    }

    #[test]
    fn dfs_order_starts_at_root_and_visits_all() {
        let t = SpaceTree::full(BBox::unit(), 2);
        let order = t.dfs_order();
        assert_eq!(order.len(), t.len());
        assert_eq!(order[0], 0);
        // parent precedes children
        let pos: HashMap<u32, usize> =
            order.iter().enumerate().map(|(p, &i)| (i, p)).collect();
        for (i, n) in t.nodes.iter().enumerate() {
            if n.parent != u32::MAX {
                assert!(pos[&n.parent] < pos[&(i as u32)]);
            }
        }
    }

    #[test]
    fn h_at_depth_halves_per_level() {
        let t = SpaceTree::full(BBox::unit(), 2);
        assert!((t.h_at_depth(0) - 1.0 / 16.0).abs() < 1e-12);
        assert!((t.h_at_depth(2) - 1.0 / 64.0).abs() < 1e-12);
    }

    #[test]
    fn rebuild_index_recovers_lookups() {
        let t = SpaceTree::full(BBox::unit(), 1);
        // simulate deserialisation: nodes survive, index does not
        let mut t2 = SpaceTree {
            nodes: t.nodes.clone(),
            index: HashMap::new(),
            domain: t.domain,
        };
        assert!(t2.lookup(LocCode::ROOT.child(5)).is_none());
        t2.rebuild_index();
        assert_eq!(t2.len(), t.len());
        assert_eq!(
            t2.lookup(LocCode::ROOT.child(5)),
            t.lookup(LocCode::ROOT.child(5))
        );
    }
}
