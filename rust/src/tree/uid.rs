//! Grid **Unique Identifiers** (UIDs).
//!
//! The paper (§3.1): *"grid property stores the Unique Identifier (UID) for
//! every grid, encoding the residing rank, a rank unique identifier and its
//! location in the structure."*
//!
//! We pack all three into a `u64`:
//!
//! ```text
//!  63        44 43        24 23                     0
//! ┌────────────┬────────────┬────────────────────────┐
//! │ rank (20b) │ local (20b) │ location code (24b)    │
//! └────────────┴────────────┴────────────────────────┘
//! ```
//!
//! The location code is a *sentinelled Morton path*: a leading `1` bit
//! followed by 3 bits (child octant) per tree level, so the root is `0b1`
//! and the code length encodes the depth. 24 bits accommodate depth ≤ 7 —
//! exactly the deepest domain the paper evaluates (2048³, depth 7).
//!
//! `UID == 0` is reserved as the null/leaf marker in the `subgrid uid`
//! dataset; the root's non-empty sentinel guarantees every real grid has a
//! non-zero UID.


/// Maximum tree depth representable in the 24-bit location code.
pub const MAX_DEPTH: u32 = 7;

const RANK_BITS: u32 = 20;
const LOCAL_BITS: u32 = 20;
const LOC_BITS: u32 = 24;

/// Packed grid identifier (see module docs for layout).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Uid(pub u64);

/// Sentinelled Morton path identifying a node's position in the octree,
/// independent of the rank assignment.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct LocCode(pub u32);

impl LocCode {
    /// The root node's code: just the sentinel bit.
    pub const ROOT: LocCode = LocCode(1);

    /// Depth of the node this code addresses (root = 0).
    pub fn depth(self) -> u32 {
        debug_assert!(self.0 != 0, "invalid (empty) location code");
        (31 - self.0.leading_zeros()) / 3
    }

    /// Code of the `octant`-th child (octant < 8, bit order x|y|z).
    pub fn child(self, octant: u8) -> LocCode {
        debug_assert!(octant < 8);
        debug_assert!(self.depth() < MAX_DEPTH, "exceeds MAX_DEPTH");
        LocCode((self.0 << 3) | octant as u32)
    }

    /// Code of the parent, or `None` for the root.
    pub fn parent(self) -> Option<LocCode> {
        if self == LocCode::ROOT {
            None
        } else {
            Some(LocCode(self.0 >> 3))
        }
    }

    /// The child octant this node occupies within its parent.
    pub fn octant(self) -> u8 {
        (self.0 & 7) as u8
    }

    /// Integer cell coordinates `(i, j, k)` of this node within its level
    /// (each in `0..2^depth`), by de-interleaving the Morton path.
    pub fn coords(self) -> (u32, u32, u32) {
        let d = self.depth();
        let (mut i, mut j, mut k) = (0, 0, 0);
        for lvl in 0..d {
            let oct = (self.0 >> (3 * (d - 1 - lvl))) & 7;
            i = (i << 1) | ((oct >> 2) & 1);
            j = (j << 1) | ((oct >> 1) & 1);
            k = (k << 1) | (oct & 1);
        }
        (i, j, k)
    }

    /// Inverse of [`coords`](Self::coords): build a code from per-level cell
    /// coordinates. Returns `None` if any coordinate exceeds `2^depth`.
    pub fn from_coords(depth: u32, i: u32, j: u32, k: u32) -> Option<LocCode> {
        if depth > MAX_DEPTH || i >= 1 << depth || j >= 1 << depth || k >= 1 << depth {
            return None;
        }
        let mut code = 1u32;
        for lvl in (0..depth).rev() {
            let oct = (((i >> lvl) & 1) << 2) | (((j >> lvl) & 1) << 1) | ((k >> lvl) & 1);
            code = (code << 3) | oct;
        }
        Some(LocCode(code))
    }
}

impl Uid {
    /// The null marker used for "no child" entries in `subgrid uid`.
    pub const NULL: Uid = Uid(0);

    pub fn new(rank: u32, local: u32, loc: LocCode) -> Uid {
        debug_assert!(rank < 1 << RANK_BITS);
        debug_assert!(local < 1 << LOCAL_BITS);
        debug_assert!(loc.0 < 1 << LOC_BITS);
        Uid(((rank as u64) << (LOCAL_BITS + LOC_BITS))
            | ((local as u64) << LOC_BITS)
            | loc.0 as u64)
    }

    /// MPI rank this grid resides on.
    pub fn rank(self) -> u32 {
        (self.0 >> (LOCAL_BITS + LOC_BITS)) as u32 & ((1 << RANK_BITS) - 1)
    }

    /// Rank-local sequential identifier.
    pub fn local(self) -> u32 {
        (self.0 >> LOC_BITS) as u32 & ((1 << LOCAL_BITS) - 1)
    }

    /// Position in the tree.
    pub fn loc(self) -> LocCode {
        LocCode(self.0 as u32 & ((1 << LOC_BITS) - 1))
    }

    pub fn is_null(self) -> bool {
        self.0 == 0
    }
}

impl std::fmt::Debug for Uid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_null() {
            write!(f, "Uid(NULL)")
        } else {
            write!(
                f,
                "Uid(r{} l{} loc{:b})",
                self.rank(),
                self.local(),
                self.loc().0
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn root_code_properties() {
        assert_eq!(LocCode::ROOT.depth(), 0);
        assert_eq!(LocCode::ROOT.parent(), None);
        assert_eq!(LocCode::ROOT.coords(), (0, 0, 0));
    }

    #[test]
    fn child_parent_roundtrip() {
        let c = LocCode::ROOT.child(5).child(3).child(7);
        assert_eq!(c.depth(), 3);
        assert_eq!(c.octant(), 7);
        assert_eq!(c.parent().unwrap().octant(), 3);
        assert_eq!(c.parent().unwrap().parent().unwrap().octant(), 5);
        assert_eq!(c.parent().unwrap().parent().unwrap().parent(), Some(LocCode::ROOT));
    }

    #[test]
    fn coords_roundtrip_exhaustive_depth3() {
        for i in 0..8 {
            for j in 0..8 {
                for k in 0..8 {
                    let c = LocCode::from_coords(3, i, j, k).unwrap();
                    assert_eq!(c.coords(), (i, j, k));
                    assert_eq!(c.depth(), 3);
                }
            }
        }
    }

    #[test]
    fn from_coords_bounds() {
        assert!(LocCode::from_coords(2, 4, 0, 0).is_none());
        assert!(LocCode::from_coords(8, 0, 0, 0).is_none());
        assert!(LocCode::from_coords(2, 3, 3, 3).is_some());
    }

    #[test]
    fn uid_field_extraction() {
        let loc = LocCode::from_coords(4, 3, 9, 14).unwrap();
        let uid = Uid::new(1043, 77, loc);
        assert_eq!(uid.rank(), 1043);
        assert_eq!(uid.local(), 77);
        assert_eq!(uid.loc(), loc);
        assert!(!uid.is_null());
    }

    #[test]
    fn uid_null_is_zero() {
        assert!(Uid::NULL.is_null());
        // Root UID must be distinguishable from NULL even for rank 0 local 0.
        assert!(!Uid::new(0, 0, LocCode::ROOT).is_null());
    }

    #[test]
    fn max_depth_fits_in_code() {
        let c = LocCode::from_coords(MAX_DEPTH, 127, 0, 127).unwrap();
        assert!(c.0 < 1 << 24);
        assert_eq!(c.depth(), MAX_DEPTH);
    }

    #[test]
    fn morton_ordering_is_z_order_within_level() {
        // Z-order: increasing k is the fastest-varying dimension.
        let a = LocCode::from_coords(1, 0, 0, 0).unwrap();
        let b = LocCode::from_coords(1, 0, 0, 1).unwrap();
        let c = LocCode::from_coords(1, 0, 1, 0).unwrap();
        let d = LocCode::from_coords(1, 1, 0, 0).unwrap();
        assert!(a.0 < b.0 && b.0 < c.0 && c.0 < d.0);
    }
}
