//! **d-grids** — the computational data grids.
//!
//! Each cell of the logical grid links to a d-grid of `16³` cells storing
//! the field variables (velocities, pressure, temperature), surrounded by a
//! halo of size one for inter-grid data exchange (paper §2.2). Following the
//! paper's file layout (§3.1) each grid carries *three* generations of cell
//! data — current, previous and temporary — plus a per-cell `cell type`
//! encoding boundary conditions.


use crate::tree::uid::Uid;
use crate::{DGRID_N, NVAR};

/// Halo-padded edge length.
pub const NPAD: usize = DGRID_N + 2;
/// Values in one halo-padded field.
pub const PADDED_LEN: usize = NPAD * NPAD * NPAD;

/// Classification of a cell, stored in the `cell type` dataset.
///
/// Fluid cells are computed; the remaining variants implement the boundary
/// conditions of the scenarios in the paper (channel inflow/outflow, no-slip
/// walls and obstacle geometry, fixed-temperature surfaces).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum CellType {
    Fluid = 0,
    /// No-slip solid wall / obstacle geometry (velocity = 0).
    Solid = 1,
    /// Velocity Dirichlet inflow.
    Inflow = 2,
    /// Zero-gradient outflow.
    Outflow = 3,
    /// Solid with fixed temperature (heated lamp, human model, …).
    HeatedSolid = 4,
}

impl CellType {
    pub fn from_u8(v: u8) -> CellType {
        match v {
            1 => CellType::Solid,
            2 => CellType::Inflow,
            3 => CellType::Outflow,
            4 => CellType::HeatedSolid,
            _ => CellType::Fluid,
        }
    }

    /// Is this a solid (velocity-zero) cell?
    pub fn is_solid(self) -> bool {
        matches!(self, CellType::Solid | CellType::HeatedSolid)
    }
}

/// Flat index into a halo-padded field: `(i, j, k)` each in `0..NPAD`,
/// `(1..=N)` being the interior.
#[inline(always)]
pub fn pidx(i: usize, j: usize, k: usize) -> usize {
    (i * NPAD + j) * NPAD + k
}

/// Flat index into an interior (`N³`) array.
#[inline(always)]
pub fn iidx(i: usize, j: usize, k: usize) -> usize {
    (i * DGRID_N + j) * DGRID_N + k
}

/// One generation of field data: `NVAR` halo-padded scalar fields.
#[derive(Clone, Debug)]
pub struct FieldSet {
    /// `fields[var][pidx(i,j,k)]`, halo-padded.
    pub fields: Vec<Vec<f32>>,
}

impl FieldSet {
    pub fn zeros() -> FieldSet {
        FieldSet {
            fields: vec![vec![0.0; PADDED_LEN]; NVAR],
        }
    }

    pub fn var(&self, v: usize) -> &[f32] {
        &self.fields[v]
    }

    pub fn var_mut(&mut self, v: usize) -> &mut [f32] {
        &mut self.fields[v]
    }

    /// Copy the interior of variable `v` into `out` (length `N³`, row-major).
    pub fn extract_interior(&self, v: usize, out: &mut [f32]) {
        let f = &self.fields[v];
        for i in 0..DGRID_N {
            for j in 0..DGRID_N {
                let src = pidx(i + 1, j + 1, 1);
                let dst = iidx(i, j, 0);
                out[dst..dst + DGRID_N].copy_from_slice(&f[src..src + DGRID_N]);
            }
        }
    }

    /// Overwrite the interior of variable `v` from `data` (length `N³`).
    pub fn set_interior(&mut self, v: usize, data: &[f32]) {
        let f = &mut self.fields[v];
        for i in 0..DGRID_N {
            for j in 0..DGRID_N {
                let dst = pidx(i + 1, j + 1, 1);
                let src = iidx(i, j, 0);
                f[dst..dst + DGRID_N].copy_from_slice(&data[src..src + DGRID_N]);
            }
        }
    }
}

/// A computational data grid (paper §2.2): three generations of cell data, a
/// per-cell type array, and the owning grid's identity.
#[derive(Clone, Debug)]
pub struct DGrid {
    pub uid: Uid,
    /// Values at the current time step.
    pub cur: FieldSet,
    /// Values at the previous time step (for restart + time derivatives).
    pub prev: FieldSet,
    /// Scratch generation (tentative velocity u*, PPE rhs in `P` slot, …).
    pub temp: FieldSet,
    /// Boundary-condition class per interior cell (`N³`, values of
    /// [`CellType`]).
    pub cell_type: Vec<u8>,
}

impl DGrid {
    pub fn new(uid: Uid) -> DGrid {
        DGrid {
            uid,
            cur: FieldSet::zeros(),
            prev: FieldSet::zeros(),
            temp: FieldSet::zeros(),
            cell_type: vec![CellType::Fluid as u8; crate::DGRID_CELLS],
        }
    }

    pub fn cell_type(&self, i: usize, j: usize, k: usize) -> CellType {
        CellType::from_u8(self.cell_type[iidx(i, j, k)])
    }

    pub fn set_cell_type(&mut self, i: usize, j: usize, k: usize, t: CellType) {
        self.cell_type[iidx(i, j, k)] = t as u8;
    }

    /// Bytes of payload this grid contributes to a checkpoint (the paper's
    /// "vast majority of data": 3 field generations + cell types).
    pub fn checkpoint_bytes() -> usize {
        3 * NVAR * crate::DGRID_CELLS * 4 + crate::DGRID_CELLS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::uid::LocCode;

    #[test]
    fn extract_set_interior_roundtrip() {
        let mut fs = FieldSet::zeros();
        let data: Vec<f32> = (0..crate::DGRID_CELLS).map(|x| x as f32).collect();
        fs.set_interior(2, &data);
        let mut out = vec![0.0; crate::DGRID_CELLS];
        fs.extract_interior(2, &mut out);
        assert_eq!(out, data);
        // halo untouched
        assert_eq!(fs.var(2)[pidx(0, 5, 5)], 0.0);
        assert_eq!(fs.var(2)[pidx(NPAD - 1, 5, 5)], 0.0);
    }

    #[test]
    fn interior_and_halo_indices_disjoint() {
        let mut fs = FieldSet::zeros();
        let data = vec![1.0f32; crate::DGRID_CELLS];
        fs.set_interior(0, &data);
        let n_ones = fs.var(0).iter().filter(|&&x| x == 1.0).count();
        assert_eq!(n_ones, crate::DGRID_CELLS);
    }

    #[test]
    fn cell_type_roundtrip() {
        let mut g = DGrid::new(Uid::new(0, 0, LocCode::ROOT));
        g.set_cell_type(3, 4, 5, CellType::HeatedSolid);
        assert_eq!(g.cell_type(3, 4, 5), CellType::HeatedSolid);
        assert!(g.cell_type(3, 4, 5).is_solid());
        assert_eq!(g.cell_type(0, 0, 0), CellType::Fluid);
    }

    #[test]
    fn checkpoint_bytes_matches_paper_layout() {
        // 3 generations × 5 vars × 4096 cells × 4 B + 4096 cell types
        assert_eq!(DGrid::checkpoint_bytes(), 3 * 5 * 4096 * 4 + 4096);
    }
}
