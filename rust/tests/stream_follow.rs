//! **Stream-follow suite** — the in-transit epoch streaming contract
//! (`mpfluid::stream`), exercised over loopback TCP against a live paged
//! writer:
//!
//! * every epoch the subscriber serves is **byte-identical** to the
//!   writer's file at that epoch (checked both structurally — dataset
//!   contents must equal the epoch-stamped generator — and, at quiesce
//!   points, as a whole-file byte compare of source vs. mirror);
//! * staleness is bounded: once the writer parks, the subscriber drains
//!   to zero lag within a bounded wait, whatever the kill/reconnect
//!   history;
//! * reconnect-resync goes through file catch-up: a freshly connected
//!   subscriber lands on the current head even though it saw none of the
//!   intermediate batches — including catch-up copies raced against the
//!   live flusher;
//! * a slow consumer under the `Coalesce` policy never stalls the writer
//!   (commits keep returning while the laggard's queue merges).
//!
//! By default a few deterministic iterations run (sub-second — they ride
//! the normal `cargo test` leg). The dedicated CI job sets
//! `STREAM_SOAK_SECONDS` to keep drawing randomized kill/reconnect trials
//! until the budget expires.

use std::io::Read;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mpfluid::h5lite::codec::{self, Codec};
use mpfluid::h5lite::{Attr, Backing, Dtype, H5File};
use mpfluid::stream::{EpochPublisher, PublisherOptions, SlowConsumerPolicy, StreamSubscriber};
use mpfluid::util::rng::Rng;

const PLAIN_ROWS: u64 = 16;
const PLAIN_ELEMS: usize = 8;
const CELL_ROWS: u64 = 32;
const CELL_ELEMS: usize = 16;

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("stream_follow_{}_{}", std::process::id(), name));
    p
}

/// Extra randomized-trial budget (default: none — deterministic passes
/// only). The CI job sets `STREAM_SOAK_SECONDS=60`.
fn extra_budget() -> Duration {
    std::env::var("STREAM_SOAK_SECONDS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Duration::from_secs_f64)
        .unwrap_or(Duration::ZERO)
}

/// Contiguous dataset contents at epoch `k`.
fn plain_at(k: u64) -> Vec<f32> {
    (0..PLAIN_ROWS as usize * PLAIN_ELEMS)
        .map(|i| k as f32 * 1000.0 + i as f32)
        .collect()
}

/// Chunked dataset contents at epoch `k` — smooth so the codec engages and
/// the stream carries real compressed extents.
fn cells_at(k: u64) -> Vec<f32> {
    (0..CELL_ROWS as usize * CELL_ELEMS)
        .map(|i| k as f32 + (i as f32 * 1e-3).sin())
        .collect()
}

/// Writer-thread handshake: the verifier raises `pause`, the writer
/// finishes its current epoch, drains its flusher and raises `parked`;
/// dropping `pause` releases it.
struct WriterCtl {
    stop: AtomicBool,
    pause: AtomicBool,
    parked: AtomicBool,
    /// Last epoch whose commit returned.
    epoch: AtomicU64,
}

/// Spin the writer: epoch-stamped rewrites of a contiguous and a chunked
/// dataset, committed as fast as the image absorbs them.
fn writer_loop(mut f: H5File, ctl: Arc<WriterCtl>) {
    let plain = f.dataset("/g", "plain").unwrap();
    let cells = f.dataset("/g", "cells").unwrap();
    let mut k = 0u64;
    while !ctl.stop.load(Ordering::Relaxed) {
        if ctl.pause.load(Ordering::Relaxed) {
            f.wait_durable().unwrap();
            ctl.parked.store(true, Ordering::SeqCst);
            while ctl.pause.load(Ordering::Relaxed) && !ctl.stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(1));
            }
            ctl.parked.store(false, Ordering::SeqCst);
            continue;
        }
        k += 1;
        f.write_rows(&plain, 0, &codec::f32s_to_bytes(&plain_at(k))).unwrap();
        f.write_rows(&cells, 0, &codec::f32s_to_bytes(&cells_at(k))).unwrap();
        f.ensure_group("/g").attrs.insert("epoch".into(), Attr::I64(k as i64));
        f.commit().unwrap();
        ctl.epoch.store(k, Ordering::SeqCst);
    }
    f.wait_durable().unwrap();
}

fn make_writer(path: &std::path::Path) -> H5File {
    let mut f = H5File::create_backed(path, 1, Backing::Paged).unwrap();
    f.create_dataset("/g", "plain", Dtype::F32, &[PLAIN_ROWS, PLAIN_ELEMS as u64])
        .unwrap();
    f.create_dataset_chunked(
        "/g",
        "cells",
        Dtype::F32,
        &[CELL_ROWS, CELL_ELEMS as u64],
        8,
        Codec::SHUFFLE_DELTA_LZ,
    )
    .unwrap();
    f.commit().unwrap();
    f
}

/// Wait until `cond` holds, failing after `timeout`.
fn await_true(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Park the writer, drain the subscriber, and assert the full contract:
/// the mirror lands exactly on the writer's last committed epoch, both
/// datasets read back the epoch generator bit-exact, the mirror verifies
/// clean, and the mirror file equals the source file byte for byte.
fn verify_quiesced(
    ctl: &WriterCtl,
    publisher: &EpochPublisher,
    sub: &StreamSubscriber,
    src: &std::path::Path,
    mirror: &std::path::Path,
) -> u64 {
    ctl.pause.store(true, Ordering::SeqCst);
    await_true("writer to park", Duration::from_secs(30), || {
        ctl.parked.load(Ordering::SeqCst)
    });
    let k = ctl.epoch.load(Ordering::SeqCst);
    // bounded staleness: with the writer parked, the subscriber must drain
    // to the publisher's true head in bounded time (the piggybacked head a
    // subscriber sees trails by up to one in-flight frame, so compare
    // against the publisher side, not `lag_seqs`)
    let head = publisher.stats().head_seq;
    await_true("subscriber to drain", Duration::from_secs(30), || {
        sub.dead().is_none() && sub.progress().last_seq >= head
    });
    assert_eq!(sub.progress().lag_seqs(), 0, "drained subscriber must report zero lag");
    let rf = sub.open_file().unwrap();
    let got_k = match rf.group("/g").unwrap().attrs.get("epoch") {
        Some(Attr::I64(v)) => *v as u64,
        other => panic!("epoch attr lost on mirror: {other:?}"),
    };
    assert_eq!(got_k, k, "drained mirror must land on the last commit");
    if k > 0 {
        let plain = rf.dataset("/g", "plain").unwrap();
        let got = codec::bytes_to_f32s(&rf.read_rows(&plain, 0, PLAIN_ROWS).unwrap());
        assert_eq!(got, plain_at(k), "contiguous contents diverge at epoch {k}");
        let cells = rf.dataset("/g", "cells").unwrap();
        let got = codec::bytes_to_f32s(&rf.read_rows(&cells, 0, CELL_ROWS).unwrap());
        assert_eq!(got, cells_at(k), "chunked contents diverge at epoch {k}");
    }
    let vr = rf.verify().unwrap();
    assert!(vr.ok(), "mirror verify at epoch {k}: {:?}", vr.errors);
    drop(rf);
    assert_eq!(
        std::fs::read(src).unwrap(),
        std::fs::read(mirror).unwrap(),
        "quiesced mirror must be byte-identical to the source at epoch {k}"
    );
    ctl.pause.store(false, Ordering::SeqCst);
    k
}

/// One kill/reconnect campaign: `iterations` rounds of connect → follow a
/// few epochs → either kill the subscriber mid-stream or quiesce-verify.
fn campaign(name: &str, seed: u64, iterations: u64, deadline: Option<Instant>) {
    let src = tmp(&format!("{name}_src"));
    let mirror = tmp(&format!("{name}_mir"));
    let mut rng = Rng::new(seed);

    let publisher = EpochPublisher::bind("127.0.0.1:0", PublisherOptions::default()).unwrap();
    let f = make_writer(&src);
    publisher.attach(&f).unwrap();
    let ctl = Arc::new(WriterCtl {
        stop: AtomicBool::new(false),
        pause: AtomicBool::new(false),
        parked: AtomicBool::new(false),
        epoch: AtomicU64::new(0),
    });
    let wctl = Arc::clone(&ctl);
    let writer = std::thread::spawn(move || writer_loop(f, wctl));

    let mut rounds = 0u64;
    let mut kills = 0u64;
    let mut verified = 0u64;
    let mut last_epoch = 0u64;
    loop {
        let done = match deadline {
            Some(d) => Instant::now() >= d && rounds >= 1,
            None => rounds >= iterations,
        };
        if done {
            break;
        }
        rounds += 1;
        // reconnect-resync every round: fresh file catch-up raced against
        // the live flusher, then the retained-batch replay
        let sub = StreamSubscriber::connect(publisher.local_addr(), &src, &mirror).unwrap();
        let follow = 1 + rng.below(4);
        sub.wait_for_epochs(follow, Duration::from_secs(30)).unwrap();
        if rng.below(2) == 0 {
            // forced disconnect mid-stream: drop without draining
            kills += 1;
            drop(sub);
        } else {
            last_epoch = verify_quiesced(&ctl, &publisher, &sub, &src, &mirror);
            verified += 1;
            drop(sub);
        }
    }
    // end on a verified quiesce so every campaign asserts byte-identity at
    // least once, whatever the random kill pattern did
    let sub = StreamSubscriber::connect(publisher.local_addr(), &src, &mirror).unwrap();
    sub.wait_for_epochs(1, Duration::from_secs(30)).unwrap();
    last_epoch = verify_quiesced(&ctl, &publisher, &sub, &src, &mirror).max(last_epoch);
    drop(sub);

    ctl.stop.store(true, Ordering::SeqCst);
    writer.join().unwrap();
    publisher.shutdown();
    println!(
        "stream-follow[{name}]: {rounds} rounds ({kills} kills, {verified} quiesce-verifies), \
         final epoch {last_epoch}"
    );
    assert!(last_epoch > 0, "campaign never observed a committed epoch");
    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&mirror).ok();
}

#[test]
fn deterministic_follow_kill_reconnect() {
    campaign("det", 0x57_2EA4, 4, None);
}

#[test]
fn randomized_soak_until_budget() {
    let budget = extra_budget();
    if budget.is_zero() {
        return;
    }
    campaign("soak", 0xF0_11_0E4, u64::MAX, Some(Instant::now() + budget));
}

/// A consumer that reads its HELLO and then nothing: the per-subscriber
/// queue fills, the `Coalesce` policy merges it, and the writer's commits
/// keep returning — the slow consumer costs it nothing but the tee.
#[test]
fn slow_consumer_coalesces_without_stalling_writer() {
    let src = tmp("coalesce_src");
    let publisher = EpochPublisher::bind(
        "127.0.0.1:0",
        PublisherOptions {
            max_queued_batches: 2,
            policy: SlowConsumerPolicy::Coalesce,
            metrics: None,
        },
    )
    .unwrap();
    let mut f = make_writer(&src);
    publisher.attach(&f).unwrap();

    let mut laggard = TcpStream::connect(publisher.local_addr()).unwrap();
    let mut hello = [0u8; 28];
    laggard.read_exact(&mut hello).unwrap();
    // big contiguous rewrites so the epochs outrun the kernel's socket
    // buffering and the bounded queue actually engages
    let big = f
        .create_dataset("/g", "big", Dtype::F32, &[512, 1024])
        .unwrap();
    let payload: Vec<f32> = (0..512 * 1024).map(|i| (i % 251) as f32).collect();

    let epochs = 40u64;
    let t0 = Instant::now();
    let mut slowest = Duration::ZERO;
    for k in 1..=epochs {
        f.write_rows(&big, 0, &codec::f32s_to_bytes(&payload)).unwrap();
        f.ensure_group("/g").attrs.insert("epoch".into(), Attr::I64(k as i64));
        let c0 = Instant::now();
        f.commit().unwrap();
        slowest = slowest.max(c0.elapsed());
    }
    let elapsed = t0.elapsed();
    let stats = publisher.stats();
    assert!(
        stats.dropped_batches > 0,
        "the laggard's queue never filled — the leg is not exercising coalesce: {stats:?}"
    );
    // "never stalls" made concrete: no single commit-return waited on the
    // dead-slow socket (a stalled writer would block for the full write
    // timeout of the laggard's TCP window, i.e. indefinitely here)
    assert!(
        slowest < Duration::from_secs(5),
        "a commit stalled {slowest:?} behind a slow consumer ({epochs} epochs in {elapsed:?})"
    );
    drop(laggard);
    f.wait_durable().unwrap();
    drop(f);
    publisher.shutdown();
    std::fs::remove_file(&src).ok();
}
