//! Regression tests for the `SnapshotReader` session API (ISSUE 5): the
//! epoch-pinned SWMR contract and the index-amortisation guarantee.
//!
//! * a session held across **2 writer commits** under the default
//!   `ReusePolicy::AfterCommit` reads byte-identical data, while a fresh
//!   open sees the new commit;
//! * dropping the session releases its pinned extents back to the free
//!   list and `H5File::verify()` stays green with the byte partition
//!   summing exactly to the file length;
//! * repeated budgeted queries through one session perform **zero**
//!   `LodIndex` rebuilds and re-read no `level_<ℓ>_locs` bytes
//!   (counter-asserted through the new `metrics` / `ReadStats` counters).

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, SnapshotOptions, ROW_BYTES};
use mpfluid::metrics::names;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::Params;
use mpfluid::tree::dgrid::DGrid;
use mpfluid::tree::sfc::{self, Partition};
use mpfluid::tree::{BBox, SpaceTree};
use mpfluid::window::{ReaderPool, SnapshotReader, SnapshotReaderOptions};
use mpfluid::{var, DGRID_CELLS};

/// Cell-data bytes of one grid row.
const RB: u64 = ROW_BYTES;

fn setup(depth: u32, ranks: u32) -> (SpaceTree, Partition, Vec<DGrid>) {
    let mut tree = SpaceTree::full(BBox::unit(), depth);
    let part = sfc::partition(&mut tree, ranks);
    let grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
    (tree, part, grids)
}

fn paint(grids: &mut [DGrid], step: u32) {
    for (i, g) in grids.iter_mut().enumerate() {
        let f = vec![i as f32 + 100.0 * step as f32; DGRID_CELLS];
        g.cur.set_interior(var::P, &f);
    }
}

fn write_file(
    name: &str,
    tree: &SpaceTree,
    part: &Partition,
    grids: &[DGrid],
) -> (H5File, ParallelIo) {
    let p = std::env::temp_dir().join(format!("rdsess_{name}_{}.h5", std::process::id()));
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), part.n_ranks as u64);
    let mut f = H5File::create(&p, 1).unwrap();
    let par = Params::isothermal(0.01, 0.1, 0.01);
    iokernel::write_common(&mut f, &par, tree, part.n_ranks as u64).unwrap();
    iokernel::write_snapshot(&mut f, &io, tree, part, grids, 0.0).unwrap();
    (f, io)
}

#[test]
fn session_pinned_across_two_commits_reads_identical_bytes() {
    let (tree, part, mut grids) = setup(2, 4);
    paint(&mut grids, 0);
    let (mut f, io) = write_file("pin2", &tree, &part, &grids);

    // cache-less session: every read below proves the on-disk bytes, not
    // a cached copy surviving an overwrite
    let session =
        SnapshotReader::open_with(&f, 0.0, &SnapshotReaderOptions { cache_bytes: 0 }).unwrap();
    let base_full = session.window(&BBox::unit(), usize::MAX).unwrap();
    let base_lod = session.budgeted(&BBox::unit(), 8 * RB).unwrap();
    assert!(base_lod.from_pyramid);

    // K = 2 writer commits rewriting the snapshot the session reads
    // (AfterCommit is the default policy; each rewrite commits once)
    for step in 1..=2u32 {
        paint(&mut grids, step);
        iokernel::rewrite_snapshot_cells(
            &mut f,
            &io,
            &tree,
            &part,
            &grids,
            0.0,
            &SnapshotOptions::default(),
        )
        .unwrap();
    }

    // the pinned session still serves the epoch-0 bytes — full resolution
    // and the pyramid levels (the refolds retired those extents too)
    let now_full = session.window(&BBox::unit(), usize::MAX).unwrap();
    assert_eq!(base_full.len(), now_full.len());
    for (a, b) in base_full.iter().zip(&now_full) {
        assert_eq!(a.uid.0, b.uid.0);
        assert_eq!(a.data, b.data, "pinned session read rewritten cell data");
    }
    let now_lod = session.budgeted(&BBox::unit(), 8 * RB).unwrap();
    assert_eq!(base_lod.level, now_lod.level);
    for (a, b) in base_lod.grids.iter().zip(&now_lod.grids) {
        assert_eq!(a.data, b.data, "pinned session read a refolded pyramid");
    }

    // a fresh open sees the new commit
    let fresh = SnapshotReader::open(&f, 0.0).unwrap();
    let new_full = fresh.window(&BBox::unit(), usize::MAX).unwrap();
    let p_at = |w: &[mpfluid::window::WindowGrid]| w[0].data[var::P * DGRID_CELLS];
    assert_ne!(p_at(&base_full), p_at(&new_full), "fresh open stuck on old epoch");
    drop(fresh);

    // the writer's byte partition stays exact with the parked extents
    let pinned = f.space_stats().pinned_bytes;
    assert!(pinned > 0, "{:?}", f.space_stats());
    let rep = f.verify().unwrap();
    assert!(rep.ok(), "{:?}", rep.errors);
    assert_eq!(
        rep.live_bytes + rep.meta_bytes + rep.free_bytes + rep.leaked_bytes,
        rep.data_end,
        "pinned extents broke the partition"
    );

    // dropping the session releases the pinned extents to the free list…
    let free_before = f.space_stats().free_bytes;
    drop(session);
    let s = f.space_stats();
    assert_eq!(s.pinned_bytes, 0, "{s:?}");
    assert!(s.free_bytes >= free_before + pinned, "{s:?}");
    // …verify stays green, and the space is genuinely allocatable again
    assert!(f.verify().unwrap().ok());
    let reused_before = s.reused_bytes;
    paint(&mut grids, 3);
    iokernel::rewrite_snapshot_cells(
        &mut f,
        &io,
        &tree,
        &part,
        &grids,
        0.0,
        &SnapshotOptions::default(),
    )
    .unwrap();
    assert!(f.space_stats().reused_bytes > reused_before);
    assert!(f.verify().unwrap().ok());
    std::fs::remove_file(&f.path).ok();
}

#[test]
fn pooled_sessions_keep_byte_identity_across_commits() {
    // the ISSUE 6 shared cache must not weaken the PR-5 contract above: a
    // pooled session pinned at epoch e keeps serving epoch-e bytes across
    // writer commits. Pool budget 0 keeps nothing resident, so every read
    // below proves the on-disk bytes (single-flight still coalesces, but
    // no decoded entry survives to go stale).
    let (tree, part, mut grids) = setup(2, 4);
    paint(&mut grids, 0);
    let (mut f, io) = write_file("pool2", &tree, &part, &grids);

    let pool = ReaderPool::new(0);
    let s1 = pool.open(&f, 0.0).unwrap();
    let s2 = pool.open(&f, 0.0).unwrap(); // shares s1's parsed core + pin
    assert_eq!(s2.metrics.counter(names::READER_SHARED_OPENS), 1);
    let base_full = s1.window(&BBox::unit(), usize::MAX).unwrap();
    let base_lod = s1.budgeted(&BBox::unit(), 8 * RB).unwrap();
    assert!(base_lod.from_pyramid);

    for step in 1..=2u32 {
        paint(&mut grids, step);
        iokernel::rewrite_snapshot_cells(
            &mut f,
            &io,
            &tree,
            &part,
            &grids,
            0.0,
            &SnapshotOptions::default(),
        )
        .unwrap();
    }

    // both pooled sessions still serve the epoch-0 bytes — full resolution
    // and the pyramid
    for s in [&s1, &s2] {
        let now_full = s.window(&BBox::unit(), usize::MAX).unwrap();
        assert_eq!(base_full.len(), now_full.len());
        for (a, b) in base_full.iter().zip(&now_full) {
            assert_eq!(a.uid.0, b.uid.0);
            assert_eq!(a.data, b.data, "pooled session read rewritten cell data");
        }
        let now_lod = s.budgeted(&BBox::unit(), 8 * RB).unwrap();
        assert_eq!(base_lod.level, now_lod.level);
        for (a, b) in base_lod.grids.iter().zip(&now_lod.grids) {
            assert_eq!(a.data, b.data, "pooled session read a refolded pyramid");
        }
    }
    // a pooled open after the commits lands on the new epoch: a fresh
    // core, fresh cache keys, the new bytes
    let fresh = pool.open(&f, 0.0).unwrap();
    assert_eq!(fresh.metrics.counter(names::READER_SHARED_OPENS), 0);
    let new_full = fresh.window(&BBox::unit(), usize::MAX).unwrap();
    let p_at = |w: &[mpfluid::window::WindowGrid]| w[0].data[var::P * DGRID_CELLS];
    assert_ne!(
        p_at(&base_full),
        p_at(&new_full),
        "pooled open stuck on the old epoch"
    );
    // budget 0 really kept nothing resident — the identity above came off
    // the disk, not out of the cache
    let cs = pool.cache_stats();
    assert_eq!(cs.resident_bytes, 0, "{cs:?}");
    assert!(cs.misses > 0, "{cs:?}");
    drop(fresh);
    drop(s1);
    drop(s2);
    std::fs::remove_file(&f.path).ok();
}

#[test]
fn repeated_budgeted_queries_rebuild_no_index() {
    // the ROADMAP hot-path fix this API closes: the per-call free function
    // re-opened the LodIndex (reading every level_<ℓ>_locs dataset) on
    // every query; one session pays it exactly once. The locs datasets are
    // contiguous — never chunk-cached — so a flat physical-read counter
    // across repeats proves zero re-reads.
    let (tree, part, mut grids) = setup(2, 4);
    paint(&mut grids, 0);
    let (f, _io) = write_file("amort", &tree, &part, &grids);
    let session = SnapshotReader::open(&f, 0.0).unwrap();
    assert_eq!(session.metrics.counter(names::READER_INDEX_BUILDS), 1);
    let index_bytes = session.metrics.counter(names::READER_INDEX_BYTES);
    assert!(index_bytes > 0, "open must account its index reads");

    let roi = BBox {
        min: [0.0; 3],
        max: [0.5; 3],
    };
    // first pass warms the chunk cache with the covered cell rows
    session.budgeted(&roi, 8 * RB).unwrap();
    session.budgeted(&BBox::unit(), RB).unwrap();
    let warm = session.read_stats();
    // repeats: zero physical reads, zero index rebuilds
    for _ in 0..5 {
        session.budgeted(&roi, 8 * RB).unwrap();
        session.budgeted(&BBox::unit(), RB).unwrap();
    }
    let after = session.read_stats();
    assert_eq!(
        after.read_bytes, warm.read_bytes,
        "repeat queries re-read bytes (locs or cell data): {after:?}"
    );
    assert!(after.cache_hits > warm.cache_hits, "{after:?}");
    assert_eq!(session.metrics.counter(names::READER_INDEX_BUILDS), 1);
    assert_eq!(session.metrics.counter(names::READER_QUERIES), 12);
    std::fs::remove_file(&f.path).ok();
}
