//! Property-based tests over the core invariants, driven by the in-tree
//! mini-prop harness (`mpfluid::util::prop`): randomised trees, partitions,
//! hyperslabs, files and workloads, each checked for the properties the
//! paper's design depends on.

use mpfluid::cluster::{paper_depth6_workload, IoTuning, Machine};
use mpfluid::exchange::{self, ExchangeStats, Gen};
use mpfluid::h5lite::{codec, Dtype, H5File};
use mpfluid::nbs::{NeighbourhoodServer, Neighbour, ALL_FACES};
use mpfluid::physics::bc::DomainBc;
use mpfluid::tree::dgrid::DGrid;
use mpfluid::tree::sfc;
use mpfluid::tree::uid::{LocCode, Uid, MAX_DEPTH};
use mpfluid::tree::{BBox, SpaceTree};
use mpfluid::util::prop::check;
use mpfluid::util::rng::Rng;
use mpfluid::var;

/// Random adaptive tree with 2:1 balance.
fn random_tree(rng: &mut Rng) -> SpaceTree {
    let depth = 1 + rng.below(3) as u32;
    let cx = rng.f64();
    let cy = rng.f64();
    let cz = rng.f64();
    if rng.bool() {
        SpaceTree::full(BBox::unit(), depth.min(2))
    } else {
        SpaceTree::adaptive(BBox::unit(), depth, &move |b: &BBox, _| {
            b.contains_point([cx, cy, cz])
        })
    }
}

#[test]
fn prop_uid_pack_unpack_roundtrip() {
    check("uid roundtrip", 0xA1, |rng| {
        let depth = rng.below(MAX_DEPTH as u64 + 1) as u32;
        let side = 1u32 << depth;
        let (i, j, k) = (
            rng.below(side as u64) as u32,
            rng.below(side as u64) as u32,
            rng.below(side as u64) as u32,
        );
        let loc = LocCode::from_coords(depth, i, j, k).unwrap();
        let rank = rng.below(1 << 20) as u32;
        let local = rng.below(1 << 20) as u32;
        let uid = Uid::new(rank, local, loc);
        assert_eq!(uid.rank(), rank);
        assert_eq!(uid.local(), local);
        assert_eq!(uid.loc(), loc);
        assert_eq!(uid.loc().coords(), (i, j, k));
        assert!(!uid.is_null());
    });
}

#[test]
fn prop_partition_complete_balanced_contiguous() {
    check("partition invariants", 0xA2, |rng| {
        let mut tree = random_tree(rng);
        let ranks = 1 + rng.below(16) as u32;
        let part = sfc::partition(&mut tree, ranks);
        // completeness
        assert_eq!(part.counts.iter().sum::<u32>() as usize, tree.len());
        assert_eq!(part.curve.len(), tree.len());
        // balance ±1
        let nonzero: Vec<u32> = part.counts.clone();
        let max = *nonzero.iter().max().unwrap();
        let min = *nonzero.iter().min().unwrap();
        assert!(max - min <= 1);
        // contiguity along the curve + root on rank 0
        let ranks_on_curve: Vec<u32> =
            part.curve.iter().map(|&i| tree.node(i).rank).collect();
        assert!(ranks_on_curve.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(tree.node(0).rank, 0);
        assert_eq!(tree.node(0).local, 0);
        // row offsets are a prefix sum reaching the total
        let off = part.row_offsets();
        assert_eq!(off[0], 0);
        assert_eq!(*off.last().unwrap() as usize, tree.len());
    });
}

#[test]
fn prop_hyperslabs_disjoint_and_cover() {
    check("hyperslab cover", 0xA3, |rng| {
        let mut tree = random_tree(rng);
        let ranks = 1 + rng.below(8) as u32;
        let part = sfc::partition(&mut tree, ranks);
        let off = part.row_offsets();
        // every rank's [off[r], off[r+1]) is disjoint and the union covers
        let mut seen = vec![false; tree.len()];
        for r in 0..ranks as usize {
            for row in off[r]..off[r + 1] {
                assert!(!seen[row as usize], "row {row} written twice");
                seen[row as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    });
}

#[test]
fn prop_neighbour_relation_is_symmetric() {
    check("neighbour symmetry", 0xA4, |rng| {
        let mut tree = random_tree(rng);
        sfc::partition(&mut tree, 4);
        let nbs = NeighbourhoodServer::new(tree);
        for idx in 0..nbs.tree.len() as u32 {
            for face in ALL_FACES {
                if let Neighbour::Same { idx: nb } = nbs.neighbour(idx, face) {
                    // symmetry holds when both sides have the same leaf-ness
                    // (a leaf looking at a *refined* same-level node gets
                    // Finer on the way back — by design)
                    let a_leaf = nbs.tree.node(idx).is_leaf();
                    let b_leaf = nbs.tree.node(nb).is_leaf();
                    if a_leaf == b_leaf {
                        match nbs.neighbour(nb, face.opposite()) {
                            Neighbour::Same { idx: back } => assert_eq!(back, idx),
                            other => panic!("asymmetric: {other:?}"),
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn prop_bottom_up_preserves_mean() {
    check("restriction conserves mean", 0xA5, |rng| {
        let mut tree = SpaceTree::full(BBox::unit(), 1);
        sfc::partition(&mut tree, 2);
        let nbs = NeighbourhoodServer::new(tree);
        let mut grids: Vec<DGrid> =
            nbs.tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        let mut child_sum = 0.0f64;
        for idx in nbs.tree.nodes_at_depth(1) {
            let mut f = vec![0.0f32; mpfluid::DGRID_CELLS];
            rng.fill_f32(&mut f, -2.0, 2.0);
            child_sum += f.iter().map(|&x| x as f64).sum::<f64>();
            grids[idx as usize].cur.set_interior(var::T, &f);
        }
        let mut stats = ExchangeStats::default();
        exchange::bottom_up(&nbs, &mut grids, Gen::Cur, &[var::T], &mut stats);
        let mut parent = vec![0.0f32; mpfluid::DGRID_CELLS];
        grids[0].cur.extract_interior(var::T, &mut parent);
        let parent_sum: f64 = parent.iter().map(|&x| x as f64).sum();
        // each parent cell = mean of 8 children cells ⇒ total sum / 8
        let rel = (parent_sum - child_sum / 8.0).abs() / child_sum.abs().max(1.0);
        assert!(rel < 1e-4, "parent {parent_sum} vs child/8 {}", child_sum / 8.0);
    });
}

#[test]
fn prop_horizontal_exchange_is_consistent() {
    check("ghost equals neighbour face", 0xA6, |rng| {
        let mut tree = SpaceTree::full(BBox::unit(), 1 + rng.below(2) as u32);
        sfc::partition(&mut tree, 1 + rng.below(6) as u32);
        let nbs = NeighbourhoodServer::new(tree);
        let mut grids: Vec<DGrid> =
            nbs.tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        for g in grids.iter_mut() {
            let mut f = vec![0.0f32; mpfluid::DGRID_CELLS];
            rng.fill_f32(&mut f, -1.0, 1.0);
            g.cur.set_interior(var::P, &f);
        }
        let mut stats = ExchangeStats::default();
        exchange::horizontal(
            &nbs,
            &mut grids,
            Gen::Cur,
            &[var::P],
            &DomainBc::all_walls(),
            &mut stats,
        );
        // pick random same-level pairs and verify ghost == neighbour face
        use mpfluid::tree::dgrid::pidx;
        let n = mpfluid::DGRID_N;
        for idx in 0..grids.len() as u32 {
            if let Neighbour::Same { idx: nb } = nbs.neighbour(idx, mpfluid::nbs::Face::XP) {
                let a = rng.range(1, n + 1);
                let b = rng.range(1, n + 1);
                let ghost = grids[idx as usize].cur.var(var::P)[pidx(n + 1, a, b)];
                let src = grids[nb as usize].cur.var(var::P)[pidx(1, a, b)];
                assert_eq!(ghost, src);
            }
        }
    });
}

#[test]
fn prop_h5lite_roundtrip_random_layout() {
    check("h5lite roundtrip", 0xA7, |rng| {
        let path = std::env::temp_dir().join(format!(
            "h5prop_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        let alignment = [1u64, 512, 4096][rng.below(3) as usize];
        let n_groups = 1 + rng.below(4) as usize;
        let mut expected: Vec<(String, String, Vec<u64>)> = Vec::new();
        {
            let mut f = H5File::create(&path, alignment).unwrap();
            for gi in 0..n_groups {
                let gpath = format!("/sim/g{gi}");
                let n_ds = 1 + rng.below(3) as usize;
                for di in 0..n_ds {
                    let rows = 1 + rng.below(20);
                    let cols = 1 + rng.below(16);
                    let ds = f
                        .create_dataset(&gpath, &format!("d{di}"), Dtype::U64, &[rows, cols])
                        .unwrap();
                    let data: Vec<u64> = (0..rows * cols)
                        .map(|_| rng.next_u64() % 1000)
                        .collect();
                    f.write_rows(&ds, 0, &codec::u64s_to_bytes(&data)).unwrap();
                    expected.push((gpath.clone(), format!("d{di}"), data));
                }
            }
            f.commit().unwrap();
        }
        let f = H5File::open(&path).unwrap();
        assert_eq!(f.alignment, alignment);
        for (gpath, name, data) in expected {
            let ds = f.dataset(&gpath, &name).unwrap();
            assert_eq!(f.read_all_u64(&ds).unwrap(), data);
            assert_eq!(ds.contiguous_offset().unwrap() % alignment, 0);
        }
        std::fs::remove_file(&path).ok();
    });
}

/// Codec invariant (format v2): encode→decode is the identity for every
/// codec, element width and buffer size — exercised exactly at the chunk
/// boundaries (0, 1, chunk−1, chunk, chunk+1 rows' worth of bytes).
#[test]
fn prop_codec_identity_across_chunk_boundaries() {
    use mpfluid::h5lite::codec::ALL_CODECS;
    const CHUNK_ROWS: u64 = 8;
    check("codec identity", 0xB1, |rng| {
        let codec = ALL_CODECS[rng.below(ALL_CODECS.len() as u64) as usize];
        let row_elems = 1 + rng.below(24) as usize;
        let rows = [0, 1, CHUNK_ROWS - 1, CHUNK_ROWS, CHUNK_ROWS + 1][rng.below(5) as usize];
        let n = rows as usize * row_elems;
        let (raw, elem_size): (Vec<u8>, usize) = if rng.bool() {
            // random f32 rows
            let mut v = vec![0.0f32; n];
            rng.fill_f32(&mut v, -1e3, 1e3);
            (codec::f32s_to_bytes(&v), 4)
        } else {
            // random u64 rows
            let v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            (codec::u64s_to_bytes(&v), 8)
        };
        let enc = codec.encode(&raw, elem_size);
        let dec = codec.decode(&enc, elem_size, raw.len()).unwrap();
        assert_eq!(dec, raw, "{codec:?} rows={rows} elems={row_elems}");
        assert_eq!(
            codec::checksum32(&dec),
            codec::checksum32(&raw),
            "checksum stability"
        );
    });
}

/// Adaptive-selector invariant (codec v2): for any input class and base
/// codec, the chosen encoding round-trips bit-exact, never expands the
/// chunk, keeps the raw checksum, and its recorded codec byte is
/// consistent with what was stored (`Store` ⇔ no codec).
#[test]
fn prop_adaptive_selection_never_expands() {
    use mpfluid::h5lite::codec::{checksum32, encode_chunk_adaptive, Codec};
    check("adaptive never expands", 0xB7, |rng| {
        let n = 1 + rng.below(16384) as usize;
        let raw: Vec<u8> = match rng.below(4) {
            0 => (0..n).map(|_| (rng.next_u64() >> 24) as u8).collect(),
            1 => vec![(rng.next_u64() & 0xFF) as u8; n],
            2 => {
                let mut v = vec![0.0f32; n / 4 + 1];
                rng.fill_f32(&mut v, 0.9, 1.1);
                let mut b = codec::f32s_to_bytes(&v);
                b.truncate(n);
                b
            }
            _ => (0..n).map(|i| (i / 7) as u8).collect(),
        };
        let base =
            [Codec::LZ, Codec::SHUFFLE_LZ, Codec::SHUFFLE_DELTA_LZ][rng.below(3) as usize];
        let es = [1usize, 4, 8][rng.below(3) as usize];
        let enc = encode_chunk_adaptive(base, &raw, es);
        assert_eq!(enc.checksum, checksum32(&raw));
        match (&enc.stored, enc.codec) {
            (Some(stored), Some(applied)) => {
                assert!(stored.len() < raw.len(), "{applied:?} expanded the chunk");
                assert_eq!(
                    applied.decode(stored, es, raw.len()).unwrap(),
                    raw,
                    "{applied:?} from base {base:?}"
                );
                assert_eq!(applied.without_entropy(), base.without_entropy());
            }
            (None, None) => {}
            _ => panic!("stored/codec out of sync"),
        }
    });
}

/// Chunked storage invariant: whatever rows land through write_rows, in
/// whatever order and chunk alignment, read_rows returns them bit-exact —
/// and matches a plain contiguous dataset fed the same writes.
#[test]
fn prop_chunked_dataset_matches_contiguous() {
    use mpfluid::h5lite::codec::Codec;
    check("chunked == contiguous", 0xB2, |rng| {
        let path = std::env::temp_dir().join(format!(
            "chunkprop_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        let rows = 1 + rng.below(40);
        let cols = 1 + rng.below(8);
        let chunk_rows = 1 + rng.below(12);
        let codec_pick = [
            Codec::LZ,
            Codec::SHUFFLE_LZ,
            Codec::SHUFFLE_DELTA_LZ,
            Codec::LZ_RC,
            Codec::SHUFFLE_DELTA_LZ_RC,
            Codec::LZ_TANS,
            Codec::SHUFFLE_DELTA_LZ_TANS,
        ][rng.below(7) as usize];
        let mut f = H5File::create(&path, 1).unwrap();
        let dc = f
            .create_dataset("/g", "plain", Dtype::U64, &[rows, cols])
            .unwrap();
        let dk = f
            .create_dataset_chunked("/g", "packed", Dtype::U64, &[rows, cols], chunk_rows, codec_pick)
            .unwrap();
        // a handful of random (possibly overlapping) row-range writes
        for _ in 0..1 + rng.below(5) {
            let start = rng.below(rows);
            let span = 1 + rng.below(rows - start);
            let data: Vec<u64> = (0..span * cols).map(|_| rng.next_u64() % 512).collect();
            let bytes = codec::u64s_to_bytes(&data);
            f.write_rows(&dc, start, &bytes).unwrap();
            f.write_rows(&dk, start, &bytes).unwrap();
        }
        f.commit().unwrap();
        let f = H5File::open(&path).unwrap();
        let dc = f.dataset("/g", "plain").unwrap();
        let dk = f.dataset("/g", "packed").unwrap();
        assert_eq!(
            f.read_rows(&dk, 0, rows).unwrap(),
            f.read_rows(&dc, 0, rows).unwrap()
        );
        // random sub-range too
        let start = rng.below(rows);
        let span = 1 + rng.below(rows - start);
        assert_eq!(
            f.read_rows(&dk, start, span).unwrap(),
            f.read_rows(&dc, start, span).unwrap()
        );
        std::fs::remove_file(&path).ok();
    });
}

/// Compaction invariant (format v2.1): whatever mix of contiguous and
/// chunked datasets, random rewrites and interleaved commits produced the
/// file, `repack()` preserves every dataset bit-exact, never grows the
/// file, and the compacted result passes `verify()`.
#[test]
fn prop_repack_preserves_contents() {
    use mpfluid::h5lite::codec::Codec;
    check("repack preserves contents", 0xB3, |rng| {
        let path = std::env::temp_dir().join(format!(
            "repackprop_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        let mut f = H5File::create(&path, 1).unwrap();
        let n_ds = 1 + rng.below(3);
        let mut specs: Vec<(String, u64, u64)> = Vec::new();
        for di in 0..n_ds {
            let rows = 1 + rng.below(24);
            let cols = 1 + rng.below(8);
            let name = format!("d{di}");
            if rng.bool() {
                let chunk_rows = 1 + rng.below(8);
                f.create_dataset_chunked(
                    "/g",
                    &name,
                    Dtype::U64,
                    &[rows, cols],
                    chunk_rows,
                    Codec::LZ,
                )
                .unwrap();
            } else {
                f.create_dataset("/g", &name, Dtype::U64, &[rows, cols])
                    .unwrap();
            }
            specs.push((name, rows, cols));
        }
        let mut want: std::collections::HashMap<String, Vec<u64>> = specs
            .iter()
            .map(|(n, rows, cols)| (n.clone(), vec![0u64; (rows * cols) as usize]))
            .collect();
        // several rounds of random partial rewrites, commits interleaved
        for _ in 0..1 + rng.below(4) {
            for (name, rows, cols) in &specs {
                let ds = f.dataset("/g", name).unwrap();
                let start = rng.below(*rows);
                let span = 1 + rng.below(*rows - start);
                let data: Vec<u64> =
                    (0..span * cols).map(|_| rng.next_u64() % 997).collect();
                f.write_rows(&ds, start, &codec::u64s_to_bytes(&data)).unwrap();
                want.get_mut(name).unwrap()
                    [(start * cols) as usize..((start + span) * cols) as usize]
                    .copy_from_slice(&data);
            }
            if rng.bool() {
                f.commit().unwrap();
            }
        }
        f.commit().unwrap();
        let before = std::fs::metadata(&path).unwrap().len();
        f.repack().unwrap();
        let after = std::fs::metadata(&path).unwrap().len();
        assert!(after <= before, "repack grew the file: {after} > {before}");
        let rep = f.verify().unwrap();
        assert!(rep.ok(), "{:?}", rep.errors);
        for (name, _, _) in &specs {
            let ds = f.dataset("/g", name).unwrap();
            assert_eq!(
                &f.read_all_u64(&ds).unwrap(),
                want.get(name).unwrap(),
                "dataset {name} damaged by repack"
            );
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_window_budget_and_cover() {
    check("window selection", 0xA8, |rng| {
        let mut tree = random_tree(rng);
        sfc::partition(&mut tree, 4);
        let nbs = NeighbourhoodServer::new(tree);
        let lo = [rng.f64() * 0.5, rng.f64() * 0.5, rng.f64() * 0.5];
        let w = BBox {
            min: lo,
            max: [
                lo[0] + 0.1 + rng.f64() * 0.4,
                lo[1] + 0.1 + rng.f64() * 0.4,
                lo[2] + 0.1 + rng.f64() * 0.4,
            ],
        };
        let budget = 1 + rng.below(64) as usize;
        let sel = nbs.select_window(&w, budget);
        assert!(sel.len() <= budget.max(1), "{} > {budget}", sel.len());
        // all selected intersect the window; none is an ancestor of another
        for &i in &sel {
            assert!(nbs.tree.node(i).bbox.intersects(&w));
        }
        for &i in &sel {
            for &j in &sel {
                if i != j {
                    let (a, b) = (nbs.tree.node(i), nbs.tree.node(j));
                    let (ai, aj, ak) = a.loc.coords();
                    let (bi, bj, bk) = b.loc.coords();
                    if a.depth() < b.depth() {
                        let shift = b.depth() - a.depth();
                        assert!(
                            (ai, aj, ak) != (bi >> shift, bj >> shift, bk >> shift),
                            "ancestor included with descendant"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn prop_cluster_model_monotone_in_bytes() {
    check("model monotone in payload", 0xA9, |rng| {
        let m = if rng.bool() {
            Machine::juqueen()
        } else {
            Machine::supermuc()
        };
        let ranks = [2048u64, 4096, 8192][rng.below(3) as usize];
        let mut w1 = paper_depth6_workload(ranks);
        let mut w2 = w1;
        w1.total_bytes = 1 << (30 + rng.below(3));
        w2.total_bytes = w1.total_bytes * 2;
        let t = IoTuning::default();
        let e1 = m.estimate_write(&w1, &t);
        let e2 = m.estimate_write(&w2, &t);
        assert!(e2.seconds > e1.seconds, "{e1} !< {e2}");
    });
}

#[test]
fn prop_snapshot_roundtrip_random_state() {
    check("snapshot roundtrip", 0xAA, |rng| {
        let path = std::env::temp_dir().join(format!(
            "ckprop_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        let mut tree = random_tree(rng);
        let ranks = 1 + rng.below(6) as u32;
        let part = sfc::partition(&mut tree, ranks);
        let mut grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        for g in grids.iter_mut() {
            for v in 0..mpfluid::NVAR {
                let mut f = vec![0.0f32; mpfluid::DGRID_CELLS];
                rng.fill_f32(&mut f, -5.0, 5.0);
                g.cur.set_interior(v, &f);
            }
        }
        let io = mpfluid::pario::ParallelIo::new(
            Machine::local(),
            IoTuning::default(),
            ranks as u64,
        );
        let mut file = H5File::create(&path, 1).unwrap();
        let par = mpfluid::physics::Params::isothermal(0.01, 0.1, 0.01);
        mpfluid::iokernel::write_common(&mut file, &par, &tree, ranks as u64).unwrap();
        mpfluid::iokernel::write_snapshot(&mut file, &io, &tree, &part, &grids, 1.0).unwrap();
        let snap = mpfluid::iokernel::read_snapshot(&file, 1.0).unwrap();
        assert_eq!(snap.tree.len(), tree.len());
        // spot-check a random grid and variable
        let pick = rng.range(0, tree.len());
        let v = rng.range(0, mpfluid::NVAR);
        let back = snap.tree.lookup(tree.node(pick as u32).loc).unwrap();
        let mut a = vec![0.0f32; mpfluid::DGRID_CELLS];
        let mut b = vec![0.0f32; mpfluid::DGRID_CELLS];
        grids[pick].cur.extract_interior(v, &mut a);
        snap.grids[back as usize].cur.extract_interior(v, &mut b);
        assert_eq!(a, b);
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_lod_every_level_is_the_exact_fold_of_its_children() {
    // the pyramid invariant (ISSUE 3 satellite): every stored level-L cell
    // equals the mean-fold of its 8 level-(L−1) children — for level 1 the
    // children are the finest leaves of current_cell_data itself; an
    // adaptive tree's coarse leaves must land verbatim at their level
    use std::collections::HashMap;
    check("lod fold invariant", 0xB7, |rng| {
        let path = std::env::temp_dir().join(format!(
            "lodprop_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        let mut tree = random_tree(rng);
        let ranks = 1 + rng.below(6) as u32;
        let part = sfc::partition(&mut tree, ranks);
        let mut grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
        for g in grids.iter_mut() {
            for v in 0..mpfluid::NVAR {
                let mut f = vec![0.0f32; mpfluid::DGRID_CELLS];
                rng.fill_f32(&mut f, -3.0, 3.0);
                g.cur.set_interior(v, &f);
            }
        }
        let io = mpfluid::pario::ParallelIo::new(
            Machine::local(),
            IoTuning::default(),
            ranks as u64,
        );
        let mut file = H5File::create(&path, 1).unwrap();
        // lean snapshot: the pyramid sources current_cell_data only
        let opts = mpfluid::iokernel::SnapshotOptions {
            previous: false,
            temp: false,
            cell_type: false,
            compress: rng.bool(),
            ..mpfluid::iokernel::SnapshotOptions::default()
        };
        let rep = mpfluid::iokernel::write_snapshot_with(
            &mut file, &io, &tree, &part, &grids, 0.0, &opts,
        )
        .unwrap();
        let group = mpfluid::iokernel::ts_group(0.0);
        if tree.max_depth() == 0 {
            assert!(rep.lod.is_none());
            std::fs::remove_file(&path).ok();
            return;
        }
        assert!(rep.lod.is_some());
        let idx = mpfluid::lod::LodIndex::open(&file, &group)
            .unwrap()
            .expect("pyramid missing");
        let ds_prop = file.dataset(&group, "grid_property").unwrap();
        let row_of_loc: HashMap<u32, u64> = file
            .read_all_u64(&ds_prop)
            .unwrap()
            .iter()
            .enumerate()
            .map(|(r, &u)| (Uid(u).loc().0, r as u64))
            .collect();
        let ds_cur = file.dataset(&group, "current_cell_data").unwrap();
        let leaf_cells = |loc: LocCode| -> Vec<f32> {
            codec::bytes_to_f32s(&file.read_rows(&ds_cur, row_of_loc[&loc.0], 1).unwrap())
        };
        for l in 1..=idx.max_level() {
            let lvl = idx.level(l).unwrap();
            assert!(!lvl.locs.is_empty());
            for (row, loc) in lvl.locs.iter().enumerate() {
                let got = lvl.read_row(&file, row as u64).unwrap();
                let tree_idx = tree.lookup(*loc).expect("stored grid not in tree");
                if tree.node(tree_idx).is_leaf() {
                    // coarse leaf: verbatim copy of its source row
                    assert_eq!(got, leaf_cells(*loc), "level {l} leaf copy");
                } else {
                    let mut want = vec![0.0f32; got.len()];
                    for oct in 0..8u8 {
                        let child = loc.child(oct);
                        let child_cells = if l == 1 {
                            leaf_cells(child)
                        } else {
                            let clvl = idx.level(l - 1).unwrap();
                            let crow =
                                clvl.row_of(child).expect("child level row missing");
                            clvl.read_row(&file, crow).unwrap()
                        };
                        mpfluid::lod::fold_octant(&child_cells, &mut want, oct);
                    }
                    assert_eq!(got, want, "level {l} fold of 8 children");
                }
            }
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_json_parses_generated_documents() {
    use mpfluid::util::json::Json;
    check("json generator", 0xAB, |rng| {
        // build a random JSON document and ensure parse succeeds + agrees
        let n = rng.range(1, 6);
        let mut doc = String::from("{");
        for i in 0..n {
            if i > 0 {
                doc.push(',');
            }
            let v = rng.next_u64() % 1000;
            doc.push_str(&format!("\"k{i}\": {v}"));
        }
        doc.push('}');
        let j = Json::parse(&doc).unwrap();
        for i in 0..n {
            assert!(j.get(&format!("k{i}")).is_some());
        }
    });
}
