//! **Codec-corpus sweep** — the CI matrix leg hardening codec v2.
//!
//! A time-bounded randomized round-trip sweep over the codec space:
//! field classes (random / constant / sinusoidal / turbulent-like) ×
//! every [`Codec`] variant (both entropy backends — codes 4–6 rc and
//! 7–9 tANS — ride `ALL_CODECS`) × odd buffer sizes (chunk-boundary and
//! partial-element tails included), plus the adversarial-input property
//! tests, the codec-v2 acceptance ratio on the turbulent field, the PR-9
//! tANS throughput acceptance, and the rc-file cross-backend compat
//! proof.
//!
//! By default one deterministic pass runs (seconds — it rides the normal
//! `cargo test` leg without stretching it). The dedicated CI job sets
//! `CODEC_CORPUS_SECONDS` to keep drawing randomized cases until the
//! budget expires, so regressions fail fast on a much larger corpus
//! without slowing the main build+test leg.

use std::time::{Duration, Instant};

use mpfluid::h5lite::codec::{
    self, checksum32, encode_chunk_adaptive, lz_compress, Codec, ALL_CODECS,
};
use mpfluid::util::rng::Rng;
use mpfluid::util::synth::{noise_bytes, smooth_field, turbulent_field, TURB_SEED};

/// Extra randomized-sweep budget (default: none — one deterministic pass).
fn extra_budget() -> Duration {
    std::env::var("CODEC_CORPUS_SECONDS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Duration::from_secs_f64)
        .unwrap_or(Duration::ZERO)
}

/// One corpus input: `kind` selects the field class, `n` the byte length.
fn gen_bytes(kind: u64, n: usize, seed: u64) -> Vec<u8> {
    match kind % 4 {
        0 => noise_bytes(seed, n),
        1 => vec![(seed & 0xFF) as u8; n],
        2 => {
            let f = smooth_field(n / 4 + 1);
            let mut b = codec::f32s_to_bytes(&f);
            b.truncate(n);
            b
        }
        _ => {
            let f = turbulent_field(n / 4 + 1, seed);
            let mut b = codec::f32s_to_bytes(&f);
            b.truncate(n);
            b
        }
    }
}

/// Round-trip one (input, codec, elem-size) case through the fixed-codec
/// and the adaptive paths.
fn exercise(raw: &[u8], c: Codec, es: usize) {
    let enc = c.encode(raw, es);
    let dec = c
        .decode(&enc, es, raw.len())
        .unwrap_or_else(|e| panic!("{c:?} es={es} n={}: {e}", raw.len()));
    assert_eq!(dec, raw, "{c:?} es={es} n={}", raw.len());
    let ad = encode_chunk_adaptive(c, raw, es);
    assert_eq!(ad.checksum, checksum32(raw));
    match (&ad.stored, ad.codec) {
        (Some(stored), Some(applied)) => {
            assert!(stored.len() < raw.len(), "adaptive stored an expansion");
            assert_eq!(
                applied.decode(stored, es, raw.len()).unwrap(),
                raw,
                "{applied:?} (adaptive from {c:?})"
            );
        }
        (None, None) => {} // Store: raw bytes, nothing to decode
        other => panic!("inconsistent adaptive encoding: {:?}", other.1),
    }
}

/// Odd sizes around the interesting boundaries: literal-run edges (128),
/// chunk-ish sizes, partial-element tails for es ∈ {4, 8}.
const ODD_SIZES: [usize; 9] = [1, 3, 37, 127, 129, 1021, 4093, 8209, 32771];

#[test]
fn corpus_roundtrip_sweep() {
    // one deterministic full pass — always
    for kind in 0..4u64 {
        for &n in &ODD_SIZES {
            let raw = gen_bytes(kind, n, 0xC0DEC + kind);
            for c in ALL_CODECS {
                for es in [1usize, 4, 8] {
                    exercise(&raw, c, es);
                }
            }
        }
    }
    // randomized extension until the budget runs out (CI matrix leg)
    let deadline = Instant::now() + extra_budget();
    let mut rng = Rng::new(0x5EED_C0DE);
    let mut cases = 0u64;
    while Instant::now() < deadline {
        let kind = rng.below(4);
        let n = rng.range(1, 65536) | 1; // odd
        let raw = gen_bytes(kind, n, rng.next_u64());
        let c = ALL_CODECS[rng.below(ALL_CODECS.len() as u64) as usize];
        let es = [1usize, 4, 8][rng.below(3) as usize];
        exercise(&raw, c, es);
        cases += 1;
    }
    if cases > 0 {
        println!("codec corpus: {cases} randomized cases beyond the deterministic pass");
    }
}

#[test]
fn adversarial_inputs_roundtrip_every_codec() {
    // incompressible noise, all-zero chunks, and NaN/Inf-bearing fields
    // must round-trip through every variant
    let mut nan_field = Vec::new();
    for i in 0..8192usize {
        nan_field.push(match i % 5 {
            0 => f32::NAN,
            1 => f32::INFINITY,
            2 => f32::NEG_INFINITY,
            3 => -0.0,
            _ => f32::MIN_POSITIVE / 2.0, // subnormal
        });
    }
    let inputs: [Vec<u8>; 3] = [
        noise_bytes(0xBAD, 32768),
        vec![0u8; 32768],
        codec::f32s_to_bytes(&nan_field),
    ];
    for raw in &inputs {
        for c in ALL_CODECS {
            for es in [1usize, 4, 8] {
                exercise(raw, c, es);
            }
        }
    }
}

#[test]
fn adaptive_falls_back_to_store_on_expansion() {
    // every pipeline expands pure noise; the adaptive selector must store
    // the raw bytes and record no codec — at several sizes
    for n in [512usize, 4093, 32768] {
        let raw = noise_bytes(n as u64, n);
        for base in [Codec::LZ, Codec::SHUFFLE_LZ, Codec::SHUFFLE_DELTA_LZ] {
            let ad = encode_chunk_adaptive(base, &raw, 4);
            assert!(ad.stored.is_none(), "{base:?} n={n} stored an expansion");
            assert!(ad.codec.is_none());
        }
        // the fixed-codec helper agrees
        let (enc, _) = codec::encode_chunk(Codec::SHUFFLE_DELTA_LZ, &raw, 4);
        assert!(enc.is_none(), "n={n}");
    }
}

#[test]
fn all_zero_chunks_crush() {
    let raw = vec![0u8; 65536];
    let ad = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &raw, 4);
    let stored = ad.stored.expect("zeros must compress");
    assert!(
        stored.len() * 100 < raw.len(),
        "zeros stored {} of {}",
        stored.len(),
        raw.len()
    );
    assert_eq!(
        ad.codec.unwrap().decode(&stored, 4, raw.len()).unwrap(),
        raw
    );
}

/// The codec-v2 acceptance criterion: on the turbulent synthetic field the
/// adaptive codec improves the stored-bytes ratio ≥ 14 % over the PR-1
/// single-candidate LZ (`stored_lz1 / stored_adaptive ≥ 1.14` — the PR-9
/// tANS selection trades ~1 point of the old 1.17× for decode speed).
/// Everything here is deterministic — field, matcher, coder, tables — so
/// this is a fixed number, not a flaky measurement (Python reference:
/// ≈ 1.148).
#[test]
fn turbulent_ratio_improvement_meets_acceptance() {
    let raw = codec::f32s_to_bytes(&turbulent_field(8192, TURB_SEED));
    // PR-1 baseline: shuffle + delta + single-candidate LZ
    let mut filtered = codec::shuffle(&raw, 4);
    codec::delta_encode(&mut filtered);
    let lz1 = lz_compress(&filtered).len().min(raw.len());
    let ad = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &raw, 4);
    let stored = ad.stored.as_ref().expect("turbulent field must compress");
    let ratio_improvement = lz1 as f64 / stored.len() as f64;
    assert!(
        ratio_improvement >= 1.14,
        "adaptive {} vs single-candidate {} → {ratio_improvement:.3}x (< 1.14x)",
        stored.len(),
        lz1
    );
    // and the selection must be the tANS entropy pipeline — within the
    // selector's margin of the range coder, preferred for decode speed —
    // decoding bit-exact
    assert_eq!(ad.codec, Some(Codec::SHUFFLE_DELTA_LZ_TANS));
    assert_eq!(
        ad.codec.unwrap().decode(stored, 4, raw.len()).unwrap(),
        raw
    );
    // the give-back vs the explicit range-coder pipeline stays ≤ 3 %
    let rc = Codec::SHUFFLE_DELTA_LZ_RC.encode(&raw, 4);
    assert!(
        stored.len() * 100 <= rc.len() * 103,
        "tANS stored {} vs rc {} — give-back above 3%",
        stored.len(),
        rc.len()
    );
    // sanity on the absolute ratio: turbulent sits between smooth and noise
    let stored_ratio = stored.len() as f64 / raw.len() as f64;
    assert!(
        stored_ratio > 0.4 && stored_ratio < 0.75,
        "turbulent stored ratio {stored_ratio:.3} out of the expected band"
    );
}

/// PR-9 cross-backend compatibility: a file whose chunks carry the legacy
/// range-coder codec bytes (4–6) must decode byte-identically through the
/// composable `CodecSpec` API — the refactor changed the type, not one
/// stored bit. Writes with explicit rc frames + codec bytes, reopens from
/// disk, and re-reads.
#[test]
fn rc_coded_file_decodes_identically_through_codecspec() {
    use mpfluid::h5lite::{Dtype, H5File};
    let p = std::env::temp_dir().join(format!(
        "codec_corpus_rc_compat_{}.h5",
        std::process::id()
    ));
    let raw = codec::f32s_to_bytes(&turbulent_field(4096, TURB_SEED));
    {
        let mut f = H5File::create(&p, 1).unwrap();
        let ds = f
            .create_dataset_chunked("/g", "d", Dtype::F32, &[16, 1024], 16, Codec::SHUFFLE_DELTA_LZ_RC)
            .unwrap();
        // explicit rc frame, recorded under the legacy byte values: the
        // exact bits a pre-CodecSpec writer committed
        let stored = Codec::SHUFFLE_DELTA_LZ_RC.encode(&raw, 4);
        f.write_chunk_encoded(
            &ds,
            0,
            &stored,
            raw.len() as u64,
            checksum32(&raw),
            Some(Codec::SHUFFLE_DELTA_LZ_RC),
        )
        .unwrap();
        f.commit().unwrap();
    }
    let f = H5File::open(&p).unwrap();
    let ds = f.dataset("/g", "d").unwrap();
    let loc = f.chunk_loc(&ds, 0).unwrap().unwrap();
    // byte 6 still means shuffle+delta+lz+rc through the composable type
    assert_eq!(loc.codec, Some(Codec::SHUFFLE_DELTA_LZ_RC));
    assert_eq!(loc.codec.unwrap().code(), 6);
    assert_eq!(f.read_rows(&ds, 0, 16).unwrap(), raw);
    std::fs::remove_file(&p).ok();
    // and at the frame level: every legacy code 0–6 maps to a codec whose
    // encode/decode round-trips the same bytes the flat enum produced
    for code in 0u8..=6 {
        let c = Codec::from_code(code).unwrap();
        assert_eq!(c.code(), code);
        let enc = c.encode(&raw, 4);
        assert_eq!(c.decode(&enc, 4, raw.len()).unwrap(), raw, "{c:?}");
    }
}

/// The PR-9 throughput acceptance on the canonical turbulent field: tANS
/// decode ≥ 2× the range coder's and encode no slower. Both backends run
/// the same LZ front end on identical token streams, so the comparison
/// isolates the entropy stage; minimum-of-N wall-clock keeps it stable
/// enough to assert even on a noisy CI box (the real margin is ~5–10×).
#[test]
fn tans_throughput_beats_range_coder() {
    let raw = codec::f32s_to_bytes(&turbulent_field(8192, TURB_SEED));
    let min_time = |f: &dyn Fn() -> usize| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            let n = f();
            assert!(n > 0);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let rc_frame = Codec::SHUFFLE_DELTA_LZ_RC.encode(&raw, 4);
    let tans_frame = Codec::SHUFFLE_DELTA_LZ_TANS.encode(&raw, 4);
    let rc_dec = min_time(&|| {
        Codec::SHUFFLE_DELTA_LZ_RC
            .decode(&rc_frame, 4, raw.len())
            .unwrap()
            .len()
    });
    let tans_dec = min_time(&|| {
        Codec::SHUFFLE_DELTA_LZ_TANS
            .decode(&tans_frame, 4, raw.len())
            .unwrap()
            .len()
    });
    assert!(
        rc_dec >= 2.0 * tans_dec,
        "tANS decode {:.1} µs vs rc {:.1} µs — acceptance needs ≥ 2x",
        tans_dec * 1e6,
        rc_dec * 1e6
    );
    let rc_enc = min_time(&|| Codec::SHUFFLE_DELTA_LZ_RC.encode(&raw, 4).len());
    let tans_enc = min_time(&|| Codec::SHUFFLE_DELTA_LZ_TANS.encode(&raw, 4).len());
    assert!(
        tans_enc <= rc_enc,
        "tANS encode {:.1} µs vs rc {:.1} µs — acceptance needs no slower",
        tans_enc * 1e6,
        rc_enc * 1e6
    );
}
