//! Cross-module integration tests: whole-system scenarios exercising the
//! coordinator, solver, I/O kernel, sliding window and TRS together (with
//! the Rust oracle backend — PJRT equivalence is covered by
//! `runtime_golden.rs`).

use std::path::PathBuf;
use std::sync::Arc;

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::coordinator::Simulation;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, vtk};
use mpfluid::nbs::Face;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::bc::{DomainBc, FaceBc};
use mpfluid::physics::RustBackend;
use mpfluid::steering::{self, SteerCommand, TrsSession};
use mpfluid::sync::{LockRank, OrderedRwLock};
use mpfluid::tree::BBox;
use mpfluid::window;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("integ_{}_{}", std::process::id(), name))
}

fn local_io(ranks: u64) -> ParallelIo {
    ParallelIo::new(Machine::local(), IoTuning::default(), ranks)
}

#[test]
fn channel_with_obstacle_develops_wake() {
    // the vortex-street scenario of Fig 6 at miniature scale: flow past a
    // cylinder must produce cross-stream (v) velocity downstream of it
    let sc = Scenario::channel(1);
    let mut sim = sc.build();
    for _ in 0..30 {
        sim.step(&RustBackend);
    }
    // sample v-velocity behind the obstacle (x>0.4 of the channel)
    let mut v_energy = 0.0f64;
    let mut buf = vec![0.0f32; mpfluid::DGRID_CELLS];
    for (i, n) in sim.nbs.tree.nodes.iter().enumerate() {
        if n.is_leaf() && n.bbox.min[0] >= 0.4 {
            sim.grids[i].cur.extract_interior(mpfluid::var::V, &mut buf);
            v_energy += buf.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
        }
    }
    assert!(v_energy > 1e-9, "no wake: v_energy={v_energy}");
    assert!(sim.kinetic_energy().is_finite());
}

#[test]
fn full_cycle_run_checkpoint_window_restart() {
    // the e2e path: run, checkpoint, offline-window the file, restart,
    // verify the restarted run continues with identical physics
    let path = tmp("cycle.h5");
    let sc = Scenario::channel(1);
    let mut sim = sc.build();
    let io = local_io(sc.ranks as u64);
    let mut trs = TrsSession::create(&path, &sim, sc.alignment).unwrap();
    for _ in 0..5 {
        sim.step(&RustBackend);
    }
    trs.checkpoint(&sim, &io).unwrap();
    let t_ck = sim.t;

    // offline sliding window on the snapshot: zoom onto the obstacle
    let file = H5File::open(&path).unwrap();
    let ts = iokernel::list_timesteps(&file);
    assert_eq!(ts.len(), 1);
    let win = window::SnapshotReader::open(&file, ts[0])
        .unwrap()
        .window(
            &BBox {
                min: [0.1, 0.3, 0.3],
                max: [0.4, 0.7, 0.7],
            },
            16,
        )
        .unwrap();
    assert!(!win.is_empty());
    assert!(win.iter().all(|g| g.data.len() == iokernel::ROW_ELEMS));

    // restart and compare against the original continuing
    let snap = iokernel::read_snapshot(&file, ts[0]).unwrap();
    let mut sim2 = Simulation::from_snapshot(snap, sc.bc);
    assert!((sim2.t - t_ck).abs() < 1e-6);
    sim.step(&RustBackend);
    sim2.step(&RustBackend);
    let (ke1, ke2) = (sim.kinetic_energy(), sim2.kinetic_energy());
    assert!(
        (ke1 - ke2).abs() < 1e-9 * ke1.abs().max(1e-12),
        "{ke1} vs {ke2}"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn trs_fig6_branching_scenarios() {
    // Fig 6's experiment structure: base run; roll back to the midpoint;
    // branch A: obstacle shifted; branch B: second obstacle added. The two
    // branches must diverge from each other and from the base run.
    let path = tmp("fig6.h5");
    let sc = Scenario::channel(1);
    let io = local_io(sc.ranks as u64);
    let mut sim = sc.build();
    let mut trs = TrsSession::create(&path, &sim, 1).unwrap();
    for _ in 0..6 {
        sim.step(&RustBackend);
    }
    trs.checkpoint(&sim, &io).unwrap();
    let t_mid = sim.t;
    for _ in 0..6 {
        sim.step(&RustBackend);
    }
    trs.checkpoint(&sim, &io).unwrap();
    let ke_base = sim.kinetic_energy();

    // branch A: shift the obstacle downstream
    let mut sim_a = trs.rollback(t_mid, &io, sc.bc).unwrap();
    steering::apply(&mut sim_a, &SteerCommand::ClearObstacles);
    steering::apply(
        &mut sim_a,
        &SteerCommand::AddObstacle {
            centre: [0.45, 0.5, 0.5],
            radius: 0.125,
            temp: None,
            ignore_axis: Some(2),
        },
    );
    for _ in 0..6 {
        sim_a.step(&RustBackend);
    }
    let ke_a = sim_a.kinetic_energy();

    // branch B (from the same ancestor file): add a second obstacle
    let file = H5File::open(&path).unwrap();
    let snap = iokernel::read_snapshot(&file, t_mid).unwrap();
    let mut sim_b = Simulation::from_snapshot(snap, sc.bc);
    steering::apply(
        &mut sim_b,
        &SteerCommand::AddObstacle {
            centre: [0.5, 0.3, 0.5],
            radius: 0.1,
            temp: None,
            ignore_axis: Some(2),
        },
    );
    for _ in 0..6 {
        sim_b.step(&RustBackend);
    }
    let ke_b = sim_b.kinetic_energy();

    assert!((sim_a.t - sim.t).abs() < 1e-9, "branches reach the same time");
    assert_ne!(ke_a, ke_base, "branch A must diverge from base");
    assert_ne!(ke_b, ke_base, "branch B must diverge from base");
    assert_ne!(ke_a, ke_b, "branches must differ from each other");
    // ancestry is recorded in the branch file
    let branch = H5File::open(&trs.active_path).unwrap();
    match branch.group("/common").unwrap().attrs.get("branched_from") {
        Some(mpfluid::h5lite::Attr::Str(s)) => assert!(s.contains("fig6")),
        other => panic!("no ancestry: {other:?}"),
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&trs.active_path).ok();
}

#[test]
fn trs_theatre_saves_simulation_cost() {
    // §4's cost argument: evaluating an altered lamp temperature via TRS
    // costs only the steps after the reload point (≈33 % in the paper's
    // 20 s + 30 s setup). Verify the step-count arithmetic end-to-end.
    let path = tmp("theatre.h5");
    let sc = Scenario::theatre(1);
    let io = local_io(sc.ranks as u64);
    let mut sim = sc.build();
    let mut trs = TrsSession::create(&path, &sim, 1).unwrap();
    let full_steps = 10u64;
    let reload_at = 4u64; // checkpoint after 4 steps ("t = 20 s")
    let mut steps_base = 0u64;
    for s in 0..full_steps {
        sim.step(&RustBackend);
        steps_base += 1;
        if s + 1 == reload_at {
            trs.checkpoint(&sim, &io).unwrap();
        }
    }
    let t_reload = trs.timesteps()[0];

    // TRS: reload, raise lamp temperature by 50 K, resume to the horizon
    let mut steered = trs.rollback(t_reload, &io, sc.bc).unwrap();
    steering::apply(&mut steered, &SteerCommand::SetHeatedSolidTemp { temp: 374.66 });
    let mut steps_trs = 0u64;
    while steered.step < full_steps - reload_at {
        steered.step(&RustBackend);
        steps_trs += 1;
    }
    assert_eq!(steps_trs, full_steps - reload_at);
    let saving = 1.0 - steps_trs as f64 / steps_base as f64;
    assert!(
        (saving - 0.4).abs() < 1e-9,
        "re-evaluation covers {saving:.0}% fewer steps"
    );
    // the steered branch really is hotter
    assert!(steered.kinetic_energy().is_finite());
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&trs.active_path).ok();
}

#[test]
fn online_collector_serves_during_simulation() {
    let sc = Scenario::cavity(1);
    let sim = sc.build();
    let shared = Arc::new(OrderedRwLock::new(LockRank::SimulationState, sim));
    let collector = window::Collector::spawn(shared.clone()).unwrap();
    // one client session, interleaving stepping and querying (front end
    // watching a live run over a single connection)
    let mut client = window::WindowClient::connect(collector.addr).unwrap();
    for _ in 0..3 {
        shared.write().unwrap().step(&RustBackend);
        let grids = client.window(&BBox::unit(), 8).unwrap();
        assert_eq!(grids.len(), 8);
    }
    let t = shared.read().unwrap().t;
    assert!(t > 0.0);
}

#[test]
fn shared_file_beats_per_process_vtk_on_modelled_machine() {
    // §3's motivation experiment at miniature scale
    let sc = Scenario::channel(1);
    let sim = sc.build();
    let io = ParallelIo::new(Machine::juqueen(), IoTuning::default(), 2048);
    let path = tmp("vs_vtk.h5");
    let mut file = H5File::create(&path, 4096).unwrap();
    iokernel::write_common(&mut file, &sim.params, &sim.nbs.tree, 2048).unwrap();
    let rep = iokernel::write_snapshot(&mut file, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
        .unwrap();

    let vtk_dir = tmp("vtk_dir");
    let vrep = vtk::write_per_process(
        &vtk_dir,
        &Machine::juqueen(),
        &sim.nbs.tree,
        &sim.part,
        &sim.grids,
        0.0,
    )
    .unwrap();
    // one shared file vs one file per rank — the management burden of §3
    assert_eq!(vrep.files_written, sim.part.n_ranks as u64);
    assert!(rep.io.bytes > 0 && vrep.bytes > 0);
    // the bandwidth claim is about production-scale payloads, where the
    // per-dataset overheads amortise: model both paths at the paper's
    // depth-6 workload (337 GB, 8192 ranks)
    let w = mpfluid::cluster::paper_depth6_workload(8192);
    let m = Machine::juqueen();
    let shared = m.estimate_write(&w, &IoTuning::default());
    let indep = m.estimate_write(
        &w,
        &IoTuning {
            collective_buffering: false,
            file_locking: false,
            alignment: false,
        },
    );
    assert!(
        shared.bandwidth > 3.0 * indep.bandwidth,
        "shared {:.2e} vs per-process {:.2e}",
        shared.bandwidth,
        indep.bandwidth
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir_all(&vtk_dir).ok();
}

#[test]
fn steering_refinement_mid_run_is_stable() {
    let sc = Scenario::cavity(1);
    let mut sim = sc.build();
    for _ in 0..2 {
        sim.step(&RustBackend);
    }
    let before = sim.nbs.tree.len();
    steering::apply(
        &mut sim,
        &SteerCommand::Refine {
            region: BBox {
                min: [0.3; 3],
                max: [0.7; 3],
            },
        },
    );
    assert!(sim.nbs.tree.len() > before);
    for _ in 0..2 {
        let rep = sim.step(&RustBackend);
        assert!(rep.div_rms.is_finite());
        assert!(rep.solve.final_residual.is_finite());
    }
    assert!(sim.kinetic_energy().is_finite());
}

#[test]
fn steering_inflow_change_takes_effect() {
    let sc = Scenario::channel(1);
    let mut sim = sc.build();
    for _ in 0..4 {
        sim.step(&RustBackend);
    }
    let ke_before = sim.kinetic_energy();
    steering::apply(
        &mut sim,
        &SteerCommand::SetFaceBc {
            face: Face::XM,
            bc: FaceBc::inflow(3.0, 293.0), // triple the inflow
        },
    );
    for _ in 0..4 {
        sim.step(&RustBackend);
    }
    assert!(
        sim.kinetic_energy() > ke_before,
        "stronger inflow must add energy: {} -> {}",
        ke_before,
        sim.kinetic_energy()
    );
}

#[test]
fn snapshot_file_readable_while_run_continues() {
    // offline window from a *committed* snapshot while the sim advances —
    // the "switch between online (present) and offline (past) data" use
    let path = tmp("live.h5");
    let sc = Scenario::cavity(1);
    let io = local_io(sc.ranks as u64);
    let mut sim = sc.build();
    let mut trs = TrsSession::create(&path, &sim, 1).unwrap();
    sim.step(&RustBackend);
    trs.checkpoint(&sim, &io).unwrap();
    let t0 = sim.t;
    // reader opens the file independently mid-run
    for _ in 0..2 {
        sim.step(&RustBackend);
        let file = H5File::open(&path).unwrap();
        let w = window::SnapshotReader::open(&file, t0)
            .unwrap()
            .window(&BBox::unit(), 8)
            .unwrap();
        assert_eq!(w.len(), 8);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn adaptive_scenario_runs_end_to_end() {
    let mut sc = Scenario::cavity(2);
    sc.adaptive = true;
    let mut sim = sc.build();
    let full = mpfluid::tree::SpaceTree::full(BBox::unit(), 2).len();
    assert!(sim.nbs.tree.len() < full, "adaptive tree should be smaller");
    for _ in 0..2 {
        let rep = sim.step(&RustBackend);
        assert!(rep.div_rms.is_finite());
    }
}
