//! Integration tests for the chunked + compressed storage path (h5lite
//! format v2): full-stack snapshot round-trips through `iokernel` →
//! `pario` → `h5lite`, read back through `window` and `read_snapshot`,
//! compressed and uncompressed snapshots byte-compared, plus v1-format
//! backward compatibility across reopen.

use std::path::PathBuf;

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::h5lite::{FORMAT_V1, FORMAT_V21, H5File};
use mpfluid::iokernel::{self, SnapshotOptions};
use mpfluid::pario::ParallelIo;
use mpfluid::tree::BBox;
use mpfluid::window;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("chunked_io_{}_{}", std::process::id(), name))
}

#[test]
fn compressed_and_raw_snapshots_agree_across_reopen() {
    let path = tmp("agree.h5");
    let sc = Scenario::channel(1);
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    {
        let mut f = H5File::create(&path, sc.alignment).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, sc.ranks as u64).unwrap();
        let comp = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            0.0,
            &SnapshotOptions::default(),
        )
        .unwrap();
        let raw = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            1.0,
            &SnapshotOptions::uncompressed(),
        )
        .unwrap();
        assert_eq!(comp.io.bytes, raw.io.bytes);
        assert!(
            comp.io.stored_bytes < raw.io.stored_bytes,
            "cell data must compress: {} vs {}",
            comp.io.stored_bytes,
            raw.io.stored_bytes
        );
    }

    // fresh handle: everything below goes through the decoded footer
    let f = H5File::open(&path).unwrap();
    assert_eq!(f.version(), FORMAT_V21);

    // byte-compare every dataset of the two snapshots
    for name in iokernel::DATASETS {
        let a = f.dataset(&iokernel::ts_group(0.0), name).unwrap();
        let b = f.dataset(&iokernel::ts_group(1.0), name).unwrap();
        assert_eq!(a.shape, b.shape, "{name}");
        assert_eq!(
            f.read_rows(&a, 0, a.shape[0]).unwrap(),
            f.read_rows(&b, 0, b.shape[0]).unwrap(),
            "dataset {name} differs between compressed and raw"
        );
    }

    // restart path: both snapshots restore identical states
    let s0 = iokernel::read_snapshot(&f, 0.0).unwrap();
    let s1 = iokernel::read_snapshot(&f, 1.0).unwrap();
    assert_eq!(s0.tree.len(), s1.tree.len());
    for (g0, g1) in s0.grids.iter().zip(&s1.grids) {
        assert_eq!(g0.cur.fields, g1.cur.fields);
        assert_eq!(g0.prev.fields, g1.prev.fields);
        assert_eq!(g0.temp.fields, g1.temp.fields);
    }

    // window path: zoomed reads agree grid-for-grid
    let win = BBox {
        min: [0.1, 0.2, 0.2],
        max: [0.5, 0.8, 0.8],
    };
    let w0 = window::SnapshotReader::open(&f, 0.0)
        .unwrap()
        .window(&win, 32)
        .unwrap();
    let w1 = window::SnapshotReader::open(&f, 1.0)
        .unwrap()
        .window(&win, 32)
        .unwrap();
    assert!(!w0.is_empty());
    assert_eq!(w0.len(), w1.len());
    for (a, b) in w0.iter().zip(&w1) {
        assert_eq!(a.uid.0, b.uid.0);
        assert_eq!(a.data, b.data);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn v1_file_full_cycle_still_works() {
    // a v2 build must keep producing and consuming v1 files end to end
    let path = tmp("v1.h5");
    let sc = Scenario::channel(1);
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    {
        let mut f = H5File::create_versioned(&path, sc.alignment, FORMAT_V1).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, sc.ranks as u64).unwrap();
        iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.5)
            .unwrap();
    }
    let f = H5File::open(&path).unwrap();
    assert_eq!(f.version(), FORMAT_V1);
    assert_eq!(iokernel::list_timesteps(&f), vec![0.5]);
    let snap = iokernel::read_snapshot(&f, 0.5).unwrap();
    assert_eq!(snap.tree.len(), sim.nbs.tree.len());
    let reader = window::SnapshotReader::open(&f, 0.5).unwrap();
    let w = reader.window(&BBox::unit(), 8).unwrap();
    assert!(!w.is_empty());
    std::fs::remove_file(&path).ok();
}

#[test]
fn compressed_snapshot_shrinks_the_file() {
    // same state written twice into two files; the chunk-compressed one
    // must occupy fewer data-region bytes (real cell data compresses)
    let pa = tmp("sz_comp.h5");
    let pb = tmp("sz_raw.h5");
    let sc = Scenario::channel(1);
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    let write = |path: &PathBuf, opts: &SnapshotOptions| -> u64 {
        let mut f = H5File::create(path, 1).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, sc.ranks as u64).unwrap();
        iokernel::write_snapshot_with(
            &mut f,
            &io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            0.0,
            opts,
        )
        .unwrap();
        f.data_bytes()
    };
    let comp = write(&pa, &SnapshotOptions::default());
    let raw = write(&pb, &SnapshotOptions::uncompressed());
    assert!(comp < raw, "compressed file {comp} B !< raw file {raw} B");
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
}

#[test]
fn reader_during_append_sees_committed_snapshots() {
    // the documented offline-window-during-run use case: a writer keeps
    // appending (and steering-rewriting) snapshots while readers open the
    // same path — every open must land on a consistent committed state,
    // and an epoch-pinned SnapshotReader session opened *before* later
    // epochs keeps serving its own committed snapshot byte-identically
    // (the pin parks every extent the rewrites retire)
    let path = tmp("swmr.h5");
    let sc = Scenario::channel(1);
    let mut sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    let mut f = H5File::create(&path, sc.alignment).unwrap();
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, sc.ranks as u64).unwrap();
    iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0).unwrap();

    // a session pins the writer's epoch now and lives across later epochs;
    // a cache-less session, so every repeat read proves the on-disk bytes
    let early = window::SnapshotReader::open_with(
        &f,
        0.0,
        &window::SnapshotReaderOptions { cache_bytes: 0 },
    )
    .unwrap();
    let w0 = early.window(&BBox::unit(), 8).unwrap();
    assert!(!w0.is_empty());

    for step in 1..=3u32 {
        let t = step as f64;
        // perturb the state so every epoch writes different bytes
        for g in sim.grids.iter_mut() {
            let data = vec![step as f32; mpfluid::DGRID_CELLS];
            g.cur.set_interior(mpfluid::var::P, &data);
        }
        iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, t)
            .unwrap();
        // also rewrite the first snapshot in place (steering)
        iokernel::rewrite_snapshot_cells(
            &mut f,
            &io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            0.0,
            &SnapshotOptions::default(),
        )
        .unwrap();
        // a fresh reader after each commit sees every timestep so far
        let reader = H5File::open(&path).unwrap();
        let ts = iokernel::list_timesteps(&reader);
        assert_eq!(ts.len(), step as usize + 1, "step {step}: {ts:?}");
        for &t in &ts {
            let w = window::SnapshotReader::open(&reader, t)
                .unwrap()
                .window(&BBox::unit(), 8)
                .unwrap();
            assert!(!w.is_empty(), "step {step} t={t}");
        }
        assert!(reader.verify().unwrap().ok());

        // the early session still serves its pre-rewrite epoch-0 view at
        // EVERY later epoch — the SWMR contract the epoch pin provides
        // (the plain-handle guarantee used to last one commit only)
        let w = early.window(&BBox::unit(), 8).unwrap();
        assert_eq!(w0.len(), w.len());
        for (a, b) in w0.iter().zip(&w) {
            assert_eq!(a.uid.0, b.uid.0);
            assert_eq!(a.data, b.data, "pinned session saw rewritten bytes");
        }
    }
    // the writer's partition stays exact with the pinned extents parked
    let s = f.space_stats();
    assert!(s.pinned_bytes > 0, "{s:?}");
    assert!(f.verify().unwrap().ok());
    drop(early);
    assert_eq!(f.space_stats().pinned_bytes, 0, "drop must release the pin");
    std::fs::remove_file(&path).ok();
}
