//! Golden cross-layer test: the AOT-compiled Pallas/JAX artifacts executed
//! through PJRT (L1+L2 via [`mpfluid::runtime::PjrtBackend`]) must agree
//! with the pure-Rust oracle ([`mpfluid::physics::RustBackend`]) on
//! identical inputs — closing the loop across all three layers.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use mpfluid::physics::{ComputeBackend, Params, RustBackend};
use mpfluid::runtime::PjrtBackend;
use mpfluid::util::rng::Rng;
use mpfluid::DGRID_N;

const PAD: usize = (DGRID_N + 2) * (DGRID_N + 2) * (DGRID_N + 2);
const INT: usize = DGRID_N * DGRID_N * DGRID_N;

fn backend() -> Option<PjrtBackend> {
    match PjrtBackend::load_default() {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("SKIP runtime_golden: {e} (run `make artifacts`)");
            None
        }
    }
}

fn params() -> Params {
    Params {
        dt: 0.01,
        h: 0.125,
        nu: 0.02,
        alpha: 0.015,
        beta_g: 0.4,
        t_inf: 300.0,
        q_int: 0.05,
        rho: 1.1,
        omega: 0.857,
    }
}

fn rand(len: usize, seed: u64, lo: f32, hi: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; len];
    rng.fill_f32(&mut v, lo, hi);
    v
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
    }
    assert!(worst <= tol, "{what}: max abs diff {worst} > {tol}");
}

/// Batch sizes exercising the chunking logic: 1 (B=1 artifact), the full
/// default batch, a multiple, and a ragged tail.
fn batches(be: &PjrtBackend) -> Vec<usize> {
    let b = be.manifest.default_batch;
    vec![1, b, 2 * b, b + 3]
}

#[test]
fn jacobi_matches_oracle() {
    let Some(pjrt) = backend() else { return };
    let par = params();
    for b in batches(&pjrt) {
        let p = rand(b * PAD, 1, -1.0, 1.0);
        let rhs = rand(b * INT, 2, -1.0, 1.0);
        let mut got = vec![0.0; b * INT];
        let mut want = vec![0.0; b * INT];
        pjrt.jacobi(b, &p, &rhs, &par, &mut got);
        RustBackend.jacobi(b, &p, &rhs, &par, &mut want);
        assert_close(&got, &want, 1e-5, &format!("jacobi b={b}"));
    }
}

#[test]
fn residual_matches_oracle() {
    let Some(pjrt) = backend() else { return };
    let par = params();
    for b in batches(&pjrt) {
        let p = rand(b * PAD, 3, -1.0, 1.0);
        let rhs = rand(b * INT, 4, -1.0, 1.0);
        let (mut r1, mut s1) = (vec![0.0; b * INT], vec![0.0; b]);
        let (mut r2, mut s2) = (vec![0.0; b * INT], vec![0.0; b]);
        pjrt.residual(b, &p, &rhs, &par, &mut r1, &mut s1);
        RustBackend.residual(b, &p, &rhs, &par, &mut r2, &mut s2);
        assert_close(&r1, &r2, 2e-3, &format!("residual field b={b}"));
        for (a, c) in s1.iter().zip(&s2) {
            assert!(
                (a - c).abs() / c.max(1.0) < 1e-3,
                "residual ssq b={b}: {a} vs {c}"
            );
        }
    }
}

#[test]
fn divergence_matches_oracle() {
    let Some(pjrt) = backend() else { return };
    let par = params();
    for b in batches(&pjrt) {
        let u = rand(b * PAD, 5, -1.0, 1.0);
        let v = rand(b * PAD, 6, -1.0, 1.0);
        let w = rand(b * PAD, 7, -1.0, 1.0);
        let mut got = vec![0.0; b * INT];
        let mut want = vec![0.0; b * INT];
        pjrt.divergence(b, &u, &v, &w, &par, &mut got);
        RustBackend.divergence(b, &u, &v, &w, &par, &mut want);
        assert_close(&got, &want, 1e-3, &format!("divergence b={b}"));
    }
}

#[test]
fn correct_matches_oracle() {
    let Some(pjrt) = backend() else { return };
    let par = params();
    for b in batches(&pjrt) {
        let u = rand(b * INT, 8, -1.0, 1.0);
        let v = rand(b * INT, 9, -1.0, 1.0);
        let w = rand(b * INT, 10, -1.0, 1.0);
        let p = rand(b * PAD, 11, -1.0, 1.0);
        let (mut u1, mut v1, mut w1) =
            (vec![0.0; b * INT], vec![0.0; b * INT], vec![0.0; b * INT]);
        let (mut u2, mut v2, mut w2) = (u1.clone(), v1.clone(), w1.clone());
        pjrt.correct(b, &u, &v, &w, &p, &par, &mut u1, &mut v1, &mut w1);
        RustBackend.correct(b, &u, &v, &w, &p, &par, &mut u2, &mut v2, &mut w2);
        assert_close(&u1, &u2, 1e-4, "correct u");
        assert_close(&v1, &v2, 1e-4, "correct v");
        assert_close(&w1, &w2, 1e-4, "correct w");
    }
}

#[test]
fn predictor_matches_oracle() {
    let Some(pjrt) = backend() else { return };
    let par = params();
    for b in batches(&pjrt) {
        let u = rand(b * PAD, 12, -1.0, 1.0);
        let v = rand(b * PAD, 13, -1.0, 1.0);
        let w = rand(b * PAD, 14, -1.0, 1.0);
        let t = rand(b * PAD, 15, 290.0, 320.0);
        let mut o1 = vec![vec![0.0f32; b * INT]; 4];
        let mut o2 = vec![vec![0.0f32; b * INT]; 4];
        {
            let [a, bb, c, d] = &mut o1[..] else { unreachable!() };
            pjrt.predictor(b, &u, &v, &w, &t, &par, a, bb, c, d);
        }
        {
            let [a, bb, c, d] = &mut o2[..] else { unreachable!() };
            RustBackend.predictor(b, &u, &v, &w, &t, &par, a, bb, c, d);
        }
        for (i, name) in ["u*", "v*", "w*", "T'"].iter().enumerate() {
            assert_close(&o1[i], &o2[i], 5e-3, &format!("predictor {name} b={b}"));
        }
    }
}

#[test]
fn restrict_matches_oracle() {
    let Some(pjrt) = backend() else { return };
    for b in batches(&pjrt) {
        let fine = rand(b * INT, 16, -1.0, 1.0);
        let mut got = vec![0.0; b * INT / 8];
        let mut want = vec![0.0; b * INT / 8];
        pjrt.restrict(b, &fine, &mut got);
        RustBackend.restrict(b, &fine, &mut want);
        assert_close(&got, &want, 1e-5, &format!("restrict b={b}"));
    }
}

#[test]
fn full_simulation_agrees_across_backends() {
    // The decisive test: an identical channel-flow simulation stepped with
    // PJRT artifacts and with the Rust oracle must produce matching
    // physics (kinetic energy within f32 accumulation noise).
    let Some(pjrt) = backend() else { return };
    use mpfluid::config::Scenario;
    let sc = Scenario::channel(1);
    let mut sim_pjrt = sc.build();
    let mut sim_rust = sc.build();
    for _ in 0..3 {
        sim_pjrt.step(&pjrt);
        sim_rust.step(&RustBackend);
    }
    let ke_p = sim_pjrt.kinetic_energy();
    let ke_r = sim_rust.kinetic_energy();
    assert!(ke_p > 0.0);
    let rel = (ke_p - ke_r).abs() / ke_r.max(1e-12);
    assert!(rel < 1e-3, "KE pjrt {ke_p} vs rust {ke_r} (rel {rel})");
    assert!(pjrt.dispatch_count() > 0);
}
