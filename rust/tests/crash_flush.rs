//! **Crash-flush suite** — randomized kill-the-flusher recovery trials for
//! the paged storage backend (`h5lite::store::PagedImage`).
//!
//! Each trial runs a paged-backed file through a sequence of commits, each
//! stamping an `epoch` attribute and rewriting a contiguous and a chunked
//! dataset with epoch-derived contents, with the fault-injection hook
//! ([`H5File::inject_flush_fault`]) armed at a randomized byte threshold.
//! The flusher dies at an op boundary (ops are dirty ranges split at page
//! boundaries), leaving the real file = fully-applied batch prefix + at
//! most one torn batch — the same state a machine crash mid-flush leaves
//! behind. The trial then reopens the file cold and asserts the durability
//! contract:
//!
//! * the file opens and lands on some epoch `j` with
//!   `last-durable ≤ j ≤ last-issued` (the superblock flip is a single
//!   40-byte op, so the recovered footer is always a fully committed one);
//! * the chunked dataset reads back **bit-exact** `f(j)` — chunk rewrites
//!   relocate, so epoch `j`'s extents are never touched by later writes;
//! * the contiguous dataset reads `f(j)` or `f(j+1)` — in-place rewrites
//!   are range-atomic in a batch but not epoch-versioned, the documented
//!   contract of contiguous layout under steering rewrites;
//! * `verify()` is clean and the live/meta/free/leaked partition exactly
//!   tiles the data region.
//!
//! By default a handful of deterministic trials run (sub-second — it rides
//! the normal `cargo test` leg). The dedicated CI job sets
//! `CRASH_FLUSH_SECONDS` to keep drawing randomized trials until the
//! budget expires.

use std::time::{Duration, Instant};

use mpfluid::h5lite::codec::{self, Codec};
use mpfluid::h5lite::{Attr, Backing, H5File};
use mpfluid::h5lite::Dtype;
use mpfluid::util::rng::Rng;

const PLAIN_ROWS: u64 = 16;
const PLAIN_ELEMS: usize = 8;
const CELL_ROWS: u64 = 32;
const CELL_ELEMS: usize = 16;
const EPOCHS: u64 = 6;

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("crash_flush_{}_{}", std::process::id(), name));
    p
}

/// Extra randomized-trial budget (default: none — deterministic trials
/// only). The CI matrix leg sets `CRASH_FLUSH_SECONDS=60`.
fn extra_budget() -> Duration {
    std::env::var("CRASH_FLUSH_SECONDS")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Duration::from_secs_f64)
        .unwrap_or(Duration::ZERO)
}

/// Contiguous dataset contents at epoch `k` — distinguishable per epoch
/// and per element.
fn plain_at(k: u64) -> Vec<f32> {
    (0..PLAIN_ROWS as usize * PLAIN_ELEMS)
        .map(|i| k as f32 * 1000.0 + i as f32)
        .collect()
}

/// Chunked dataset contents at epoch `k` — smooth enough for the default
/// codec to engage, so the trial exercises compressed extents + checksums.
fn cells_at(k: u64) -> Vec<f32> {
    (0..CELL_ROWS as usize * CELL_ELEMS)
        .map(|i| k as f32 + (i as f32 * 1e-3).sin())
        .collect()
}

struct TrialOutcome {
    recovered_epoch: u64,
    faulted: bool,
}

/// One kill-and-recover trial. `fault_window` is the byte span past the
/// durable epoch-0 state in which the flusher-kill threshold is drawn
/// (`None` = fault hook disarmed — the control trial).
fn trial(name: &str, seed: u64, fault_window: Option<u64>) -> TrialOutcome {
    let p = tmp(name);
    let mut rng = Rng::new(seed);

    // --- set up: epoch 0 durable on disk before the hook arms ----------
    let mut f = H5File::create_backed(&p, 1, Backing::Paged).unwrap();
    let plain = f
        .create_dataset("/g", "plain", Dtype::F32, &[PLAIN_ROWS, PLAIN_ELEMS as u64])
        .unwrap();
    let cells = f
        .create_dataset_chunked(
            "/g",
            "cells",
            Dtype::F32,
            &[CELL_ROWS, CELL_ELEMS as u64],
            8,
            Codec::SHUFFLE_DELTA_LZ,
        )
        .unwrap();
    f.write_rows(&plain, 0, &codec::f32s_to_bytes(&plain_at(0))).unwrap();
    f.write_rows(&cells, 0, &codec::f32s_to_bytes(&cells_at(0))).unwrap();
    f.ensure_group("/g").attrs.insert("epoch".into(), Attr::I64(0));
    f.commit().unwrap();
    f.wait_durable().unwrap();
    let base = f.flush_stats();
    assert_eq!(base.barriers_issued, base.barriers_durable);

    let faulted = if let Some(window) = fault_window {
        let at = base.flushed_bytes + rng.below(window.max(1));
        assert!(f.inject_flush_fault(at), "paged backend must accept the hook");
        true
    } else {
        false
    };

    // --- epochs 1..=EPOCHS: rewrite + stamp + commit --------------------
    // Commits start failing once the flusher is dead; writes into the
    // image keep succeeding. Track the epochs whose commit *returned* Ok
    // (queued — not necessarily durable).
    let mut last_ok = 0u64;
    for k in 1..=EPOCHS {
        f.write_rows(&plain, 0, &codec::f32s_to_bytes(&plain_at(k))).unwrap();
        f.write_rows(&cells, 0, &codec::f32s_to_bytes(&cells_at(k))).unwrap();
        f.ensure_group("/g").attrs.insert("epoch".into(), Attr::I64(k as i64));
        match f.commit() {
            Ok(()) => last_ok = k,
            Err(e) => {
                assert!(faulted, "commit failed without an armed fault: {e:#}");
                break;
            }
        }
    }
    // Lower bound from the pre-drop flusher counters: each commit issues
    // exactly two barriers (footer sync, superblock sync), so the k-th
    // epoch's superblock flip is durable once 2k barriers past the base
    // completed. Snapshot before drop — drop itself keeps flushing only
    // on a live flusher.
    let pre = f.flush_stats();
    let durable_floor = ((pre.barriers_durable - base.barriers_durable) / 2).min(last_ok);
    drop(f);

    // --- cold reopen through the plain direct path ----------------------
    let f = H5File::open(&p).unwrap();
    let j = match f.group("/g").unwrap().attrs.get("epoch") {
        Some(Attr::I64(v)) => *v as u64,
        other => panic!("epoch attr lost: {other:?}"),
    };
    assert!(
        j >= durable_floor && j <= last_ok,
        "recovered epoch {j} outside [{durable_floor}, {last_ok}]"
    );
    if !faulted {
        assert_eq!(j, EPOCHS, "control trial must recover the final epoch");
    }

    // chunked contents: bit-exact at the recovered epoch
    let cells = f.dataset("/g", "cells").unwrap();
    let got = codec::bytes_to_f32s(&f.read_rows(&cells, 0, CELL_ROWS).unwrap());
    assert_eq!(got, cells_at(j), "chunked contents diverge at epoch {j}");

    // contiguous contents: the in-place region is range-atomic per batch,
    // so a crash between a data batch and its superblock flip may expose
    // the *next* epoch's bytes under epoch j's footer
    let plain = f.dataset("/g", "plain").unwrap();
    let got = codec::bytes_to_f32s(&f.read_rows(&plain, 0, PLAIN_ROWS).unwrap());
    assert!(
        got == plain_at(j) || (faulted && got == plain_at(j + 1)),
        "contiguous contents at epoch {j} match neither f({j}) nor f({})",
        j + 1
    );

    // structurally clean, and the partition tiles the data region exactly
    let vr = f.verify().unwrap();
    assert!(vr.ok(), "verify after crash at epoch {j}: {:?}", vr.errors);
    assert_eq!(vr.n_datasets, 2);
    assert_eq!(
        vr.live_bytes + vr.meta_bytes + vr.free_bytes + vr.leaked_bytes,
        vr.data_end,
        "partition does not tile the data region"
    );

    std::fs::remove_file(&p).ok();
    TrialOutcome {
        recovered_epoch: j,
        faulted,
    }
}

#[test]
fn control_trial_without_fault_recovers_final_epoch() {
    let out = trial("control", 0xC0_11EC7, None);
    assert_eq!(out.recovered_epoch, EPOCHS);
    assert!(!out.faulted);
}

#[test]
fn deterministic_kill_trials_recover_a_committed_epoch() {
    // small windows kill early (epoch 1-2 in flight), large windows late
    // or never — both recovery directions are pinned deterministically
    for (i, window) in [512u64, 4096, 16384, 65536].into_iter().enumerate() {
        trial(&format!("det{i}"), 0x5EED_0 + i as u64, Some(window));
    }
}

#[test]
fn randomized_kill_trials_until_budget() {
    let deadline = Instant::now() + extra_budget();
    let mut rng = Rng::new(0xFA_17_5EED);
    let mut trials = 0u64;
    let mut faults_recovered_early = 0u64;
    while Instant::now() < deadline {
        let window = 1 + rng.below(32 * 1024);
        let out = trial("rand", rng.next_u64(), Some(window));
        trials += 1;
        if out.recovered_epoch < EPOCHS {
            faults_recovered_early += 1;
        }
    }
    if trials > 0 {
        println!(
            "crash-flush: {trials} randomized trials, \
             {faults_recovered_early} recovered to a pre-final epoch"
        );
    }
}
