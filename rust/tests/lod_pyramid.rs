//! Integration: the LOD pyramid end-to-end — fold-through-collective-write,
//! budget-aware window answers, storage overhead, and compatibility with
//! pyramid-less files. The acceptance criteria of ISSUE 3 live here:
//!
//! * a whole-domain `window` query at a budget 1/64 of full resolution
//!   reads ≤ 1/8 of the full-res bytes through the pyramid;
//! * pyramid storage overhead ≤ 15 % of the file;
//! * `H5File::verify()` stays green on pyramid-bearing files.

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, SnapshotOptions, ROW_BYTES};
use mpfluid::lod::LodIndex;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::Params;
use mpfluid::tree::dgrid::DGrid;
use mpfluid::tree::sfc::{self, Partition};
use mpfluid::tree::{BBox, SpaceTree};
use mpfluid::window;
use mpfluid::{var, DGRID_CELLS};

/// Cell-data bytes of one grid row.
const RB: u64 = ROW_BYTES;

fn setup(tree: SpaceTree, ranks: u32) -> (SpaceTree, Partition, Vec<DGrid>) {
    let mut tree = tree;
    let part = sfc::partition(&mut tree, ranks);
    let mut grids: Vec<DGrid> = tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
    for (i, g) in grids.iter_mut().enumerate() {
        let f = vec![i as f32; DGRID_CELLS];
        g.cur.set_interior(var::P, &f);
    }
    (tree, part, grids)
}

fn write_file(
    name: &str,
    tree: &SpaceTree,
    part: &Partition,
    grids: &[DGrid],
    opts: &SnapshotOptions,
) -> (H5File, iokernel::SnapshotReport) {
    let p = std::env::temp_dir().join(format!("lodint_{name}_{}.h5", std::process::id()));
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), part.n_ranks as u64);
    let mut f = H5File::create(&p, 4096).unwrap();
    let par = Params::isothermal(0.01, 0.1, 0.01);
    iokernel::write_common(&mut f, &par, tree, part.n_ranks as u64).unwrap();
    let rep = iokernel::write_snapshot_with(&mut f, &io, tree, part, grids, 0.0, opts).unwrap();
    (f, rep)
}

#[test]
fn acceptance_budget_ratio_overhead_and_verify() {
    let (tree, part, grids) = setup(SpaceTree::full(BBox::unit(), 2), 4);
    let (f, rep) = write_file("accept", &tree, &part, &grids, &SnapshotOptions::default());

    // --- budget criterion: 1/64 budget reads ≤ 1/8 of full-res bytes ----
    let reader = window::SnapshotReader::open(&f, 0.0).unwrap();
    let full = reader.budgeted(&BBox::unit(), u64::MAX).unwrap();
    assert_eq!(full.level, 0);
    assert_eq!(full.grids.len(), 64, "full resolution = the 64 leaves");
    let full_bytes = full.bytes_read;
    let budget = full_bytes / 64;
    let coarse = reader.budgeted(&BBox::unit(), budget).unwrap();
    assert!(coarse.from_pyramid);
    assert!(
        coarse.bytes_read <= budget,
        "budget burst: {} > {budget}",
        coarse.bytes_read
    );
    assert!(
        coarse.bytes_read * 8 <= full_bytes,
        "read {} of {full_bytes} — more than 1/8",
        coarse.bytes_read
    );
    assert!(!coarse.grids.is_empty());

    // --- storage criterion: pyramid ≤ 15 % of the file ------------------
    let lod_rep = rep.lod.expect("pyramid missing");
    let file_len = std::fs::metadata(&f.path).unwrap().len();
    assert!(
        lod_rep.stored_bytes * 100 <= file_len * 15,
        "pyramid {} B vs file {file_len} B",
        lod_rep.stored_bytes
    );

    // --- verify stays green on the pyramid-bearing file -----------------
    let vr = f.verify().unwrap();
    assert!(vr.ok(), "{:?}", vr.errors);
    std::fs::remove_file(&f.path).ok();
}

#[test]
fn pyramid_less_file_answers_window_queries_unchanged() {
    let (tree, part, grids) = setup(SpaceTree::full(BBox::unit(), 2), 3);
    let (with, _) = write_file("with", &tree, &part, &grids, &SnapshotOptions::default());
    let opts_off = SnapshotOptions {
        lod: false,
        ..SnapshotOptions::default()
    };
    let (without, rep) = write_file("without", &tree, &part, &grids, &opts_off);
    assert!(rep.lod.is_none());
    assert!(LodIndex::open(&without, &iokernel::ts_group(0.0))
        .unwrap()
        .is_none());
    // the classic grid-count window answers identically on both files
    let ra = window::SnapshotReader::open(&with, 0.0).unwrap();
    let rb = window::SnapshotReader::open(&without, 0.0).unwrap();
    assert!(ra.has_pyramid() && !rb.has_pyramid());
    for budget in [1usize, 8, 1000] {
        let a = ra.window(&BBox::unit(), budget).unwrap();
        let b = rb.window(&BBox::unit(), budget).unwrap();
        assert_eq!(a.len(), b.len(), "budget {budget}");
        for (ga, gb) in a.iter().zip(&b) {
            assert_eq!(ga.uid.0, gb.uid.0);
            assert_eq!(ga.data, gb.data);
        }
    }
    // and the pyramid-less file still verifies + restores
    assert!(without.verify().unwrap().ok());
    assert!(iokernel::read_snapshot(&without, 0.0).is_ok());
    std::fs::remove_file(&with.path).ok();
    std::fs::remove_file(&without.path).ok();
}

#[test]
fn adaptive_tree_budgeted_cover_tiles_the_domain() {
    // corner-refined adaptive domain: a mid-level query must tile the
    // whole domain with mixed-depth grids (stored level grids where the
    // tree is deep, coarser ancestors where a coarse leaf covers)
    let tree = SpaceTree::adaptive(BBox::unit(), 3, &|b, _| {
        b.contains_point([0.01, 0.01, 0.01])
    });
    let (tree, part, grids) = setup(tree, 4);
    let (f, rep) = write_file("adaptive", &tree, &part, &grids, &SnapshotOptions::default());
    assert_eq!(rep.lod.unwrap().levels, 3);
    // level-1 cover of the whole domain (depth-2 tiling, 64 coords)
    let reader = window::SnapshotReader::open(&f, 0.0).unwrap();
    let w = reader.budgeted(&BBox::unit(), 64 * RB).unwrap();
    assert!(w.from_pyramid);
    assert!(w.bytes_read <= 64 * RB);
    let depths: Vec<u32> = w.grids.iter().map(|g| g.depth).collect();
    assert!(
        depths.iter().any(|&d| d < 2),
        "coarse-leaf regions must answer coarser: {depths:?}"
    );
    // exact tiling: volumes sum to the domain, no pairwise overlap
    let vol = |b: &BBox| (0..3).map(|a| b.extent(a)).product::<f64>();
    let total: f64 = w.grids.iter().map(|g| vol(&g.bbox)).sum();
    assert!((total - 1.0).abs() < 1e-9, "cover volume {total}");
    for (i, a) in w.grids.iter().enumerate() {
        for b in w.grids.iter().skip(i + 1) {
            assert!(!a.bbox.intersects(&b.bbox), "{:?} overlaps {:?}", a.uid, b.uid);
        }
    }
    assert!(f.verify().unwrap().ok());
    std::fs::remove_file(&f.path).ok();
}

#[test]
fn budgeted_answers_are_consistent_across_compression() {
    // the pyramid must serve identical values whether the file stores it
    // compressed (chunked) or raw (contiguous levels)
    let (tree, part, grids) = setup(SpaceTree::full(BBox::unit(), 2), 4);
    let (fc, _) = write_file("comp", &tree, &part, &grids, &SnapshotOptions::default());
    let opts_raw = SnapshotOptions::uncompressed();
    let (fr, _) = write_file("raw", &tree, &part, &grids, &opts_raw);
    let rc = window::SnapshotReader::open(&fc, 0.0).unwrap();
    let rr = window::SnapshotReader::open(&fr, 0.0).unwrap();
    for budget in [RB, 8 * RB, u64::MAX] {
        let a = rc.budgeted(&BBox::unit(), budget).unwrap();
        let b = rr.budgeted(&BBox::unit(), budget).unwrap();
        assert_eq!(a.level, b.level);
        assert_eq!(a.grids.len(), b.grids.len());
        for (ga, gb) in a.grids.iter().zip(&b.grids) {
            assert_eq!(ga.uid.0, gb.uid.0);
            assert_eq!(ga.data, gb.data);
        }
    }
    std::fs::remove_file(&fc.path).ok();
    std::fs::remove_file(&fr.path).ok();
}
