//! Bench: **multi-tenant fan-out read serving** (ISSUE 6) — N concurrent
//! `WindowClient`s pulling mixed overlapping ROI×budget traffic from one
//! snapshot through a `Collector`, against the PR-5 baseline of N fully
//! private `SnapshotReader` sessions.
//!
//! The shared decoded-chunk cache + single-flight coalescing should turn
//! N× repeated decode work into ~1×: the table reports per-request p50/p99
//! latency, aggregate chunk decodes (shared vs. private), and bytes
//! decoded per byte served.
//!
//! Run: `cargo bench --bench fanout_load` (add `-- --quick` for the CI
//! smoke configuration, which also asserts the coalescing counter is
//! non-zero and the decode reduction is ≥4×).

use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use mpfluid::cluster::{IoTuning, Machine, ReadWorkload};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, ROW_BYTES, ROW_ELEMS};
use mpfluid::pario::ParallelIo;
use mpfluid::tree::BBox;
use mpfluid::util::fmt_bytes;
use mpfluid::window::{Collector, CollectorOptions, SnapshotReader, WindowClient};

/// Cell-data bytes of one grid row.
const RB: u64 = ROW_BYTES;
/// Wire bytes of one served grid record (uid + depth + bbox + cells).
const REC_BYTES: u64 = (8 + 4 + 48 + ROW_ELEMS * 4) as u64;

/// The overlapping regions the viewers crowd onto.
fn rois() -> [BBox; 3] {
    [
        BBox::unit(),
        BBox {
            min: [0.0; 3],
            max: [0.5; 3],
        },
        BBox {
            min: [0.25; 3],
            max: [0.75; 3],
        },
    ]
}

/// One viewer's query script: `rounds` passes over a mixed SWIN/SWLD
/// sequence, phase-shifted by the client index so the traffic overlaps
/// without being identical.
fn script(client: usize, rounds: usize) -> Vec<(BBox, Option<u64>, u32)> {
    let r = rois();
    let mut out = Vec::new();
    for round in 0..rounds {
        let a = r[(client + round) % r.len()];
        let b = r[(client + round + 1) % r.len()];
        out.push((a, None, 64)); // SWIN: 64-grid window
        out.push((b, Some(8 * RB), 0)); // SWLD: coarse byte budget
        out.push((a, Some(64 * RB), 0)); // SWLD: finer byte budget
        out.push((b, None, 8)); // SWIN: coarse window
    }
    out
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[i]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let clients = if quick { 16 } else { 64 };
    let rounds = if quick { 1 } else { 2 };

    // depth-3 cavity: 585 grids, 512 leaves — ~47 MiB of chunked,
    // compressed cell data plus the LOD pyramid
    let mut sc = Scenario::cavity(3);
    sc.ranks = 8;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
    let path = std::env::temp_dir().join(format!("fanout_bench_{}.h5", std::process::id()));
    let mut f = H5File::create(&path, 4096).unwrap();
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 8).unwrap();
    iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0).unwrap();

    // == PR-5 baseline: N fully private sessions, same traffic ============
    // each session decodes its own chunks into its own cache; the aggregate
    // decode count is what the shared cache exists to collapse
    let t0 = Instant::now();
    let mut base_decodes = 0u64;
    let mut base_decoded_bytes = 0u64;
    for c in 0..clients {
        let r = SnapshotReader::open(&f, 0.0).unwrap();
        for (roi, lod, grids) in script(c, rounds) {
            match lod {
                Some(budget) => {
                    r.budgeted(&roi, budget).unwrap();
                }
                None => {
                    r.window(&roi, grids as usize).unwrap();
                }
            }
        }
        let rs = r.read_stats();
        base_decodes += rs.cache_misses;
        base_decoded_bytes += rs.read_bytes;
    }
    let base_elapsed = t0.elapsed();

    // == fan-out: one Collector, N concurrent WindowClients ===============
    // one worker per client so every session really is concurrent; the
    // barrier releases the whole fleet into the same first query to
    // stampede the cold cache
    let opts = CollectorOptions {
        workers: clients,
        backlog: clients,
        ..CollectorOptions::default()
    };
    let f = H5File::open(&path).unwrap();
    let collector = Collector::spawn_snapshot(f, 0.0, &opts).unwrap();
    let addr = collector.addr;
    let start = Arc::new(Barrier::new(clients));
    let latencies = Arc::new(Mutex::new(Vec::<f64>::new()));
    let served = Arc::new(Mutex::new(0u64));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let start = Arc::clone(&start);
        let latencies = Arc::clone(&latencies);
        let served = Arc::clone(&served);
        handles.push(std::thread::spawn(move || {
            let mut client = WindowClient::connect(addr).unwrap();
            start.wait();
            // stampede: everyone asks for the same full-domain cover first
            let mut lats = Vec::new();
            let mut bytes = 0u64;
            let mut run = |client: &mut WindowClient, roi: &BBox, lod: Option<u64>, grids: u32| {
                let q0 = Instant::now();
                let n = match lod {
                    Some(budget) => client.budgeted(roi, budget).unwrap().grids.len(),
                    None => client.window(roi, grids).unwrap().len(),
                };
                lats.push(q0.elapsed().as_secs_f64() * 1e3);
                bytes += n as u64 * REC_BYTES;
            };
            run(&mut client, &BBox::unit(), Some(64 * RB), 0);
            for (roi, lod, grids) in script(c, rounds) {
                run(&mut client, &roi, lod, grids);
            }
            latencies.lock().unwrap().extend(lats);
            *served.lock().unwrap() += bytes;
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let fan_elapsed = t0.elapsed();

    let pool = collector.reader_pool().unwrap();
    let cs = pool.cache_stats();
    let served = *served.lock().unwrap();
    let mut lats = latencies.lock().unwrap().clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let reduction = base_decodes as f64 / cs.misses.max(1) as f64;

    println!(
        "fan-out load: {clients} concurrent clients × {} queries (+1 stampede), \
         overlapping ROIs",
        4 * rounds
    );
    println!(
        "{:>22} {:>12} {:>14} {:>12}",
        "path", "wall", "chunk decodes", "decoded"
    );
    println!(
        "{:>22} {:>9.0} ms {:>14} {:>12}",
        "private sessions",
        base_elapsed.as_secs_f64() * 1e3,
        base_decodes,
        fmt_bytes(base_decoded_bytes),
    );
    println!(
        "{:>22} {:>9.0} ms {:>14} {:>12}",
        "shared pool",
        fan_elapsed.as_secs_f64() * 1e3,
        cs.misses,
        fmt_bytes(cs.loaded_bytes),
    );
    println!(
        "  decode reduction ×{reduction:.1}; coalesced waits {}; shared opens {}; \
         cache hits {} ({} resident, {} evictions)",
        cs.coalesced,
        pool.metrics()
            .counter(mpfluid::metrics::names::READER_SHARED_OPENS),
        cs.hits,
        fmt_bytes(cs.resident_bytes),
        cs.evictions,
    );
    println!(
        "  latency p50 {:.2} ms  p99 {:.2} ms  (n={})",
        percentile(&lats, 0.50),
        percentile(&lats, 0.99),
        lats.len()
    );
    println!(
        "  bytes decoded per byte served: {:.3} ({} decoded / {} served)",
        cs.loaded_bytes as f64 / served.max(1) as f64,
        fmt_bytes(cs.loaded_bytes),
        fmt_bytes(served),
    );

    // the machine model's view of the same dedup (ISSUE 6: price shared
    // hits in the read estimate)
    let total = cs.hits + cs.misses + cs.coalesced;
    let hit_rate = (total - cs.misses) as f64 / total.max(1) as f64;
    let est = Machine::juqueen().estimate_fanout_read(
        &ReadWorkload {
            clients: clients as u64,
            bytes_per_client: served / clients as u64,
            shared_hit_rate: hit_rate,
        },
        Some(mpfluid::h5lite::codec::Codec::SHUFFLE_DELTA_LZ),
    );
    println!(
        "  modelled on JuQueen at hit rate {:.2}: {:.2} GB/s served \
         (decode {:.3}s, serve {:.3}s)",
        hit_rate,
        est.bandwidth / 1e9,
        est.t_decode,
        est.t_serve
    );

    drop(collector);
    std::fs::remove_file(&path).ok();

    if quick {
        // CI smoke: the shared cache must actually dedup and coalesce
        if cs.coalesced == 0 {
            eprintln!("FAIL: no coalesced decodes under overlapping concurrent traffic");
            std::process::exit(1);
        }
        if reduction < 4.0 {
            eprintln!("FAIL: aggregate decode reduction ×{reduction:.1} < ×4 vs private sessions");
            std::process::exit(1);
        }
        println!("quick check OK: coalesced {} > 0, reduction ×{reduction:.1} ≥ ×4", cs.coalesced);
    }
}
