//! Bench: **in-transit epoch streaming** (ISSUE 8) — a paged-backed writer
//! committing epochs while `stream::EpochPublisher` tees every flush batch
//! to N live `StreamSubscriber`s over loopback TCP, against the
//! file-polling alternative (reopen the shared file until the new epoch
//! shows up).
//!
//! Two claims get measured:
//!
//! * **the tee is free for the writer** — commit-return time with 8
//!   subscribers stays within 10% of the no-streaming baseline (the
//!   publish hook is O(ranges) `Arc` clones; fan-out and socket I/O happen
//!   on per-subscriber sender threads);
//! * **delivery beats polling** — commit-to-applied latency on a live
//!   subscriber undercuts the durability-wait + poll-discovery latency of
//!   reopening the file, the way the paper's §6 in-situ pipeline would.
//!
//! Run: `cargo bench --bench stream_follow` (add `-- --quick` for the CI
//! smoke configuration, which also asserts both claims).

use std::time::{Duration, Instant};

use mpfluid::cluster::{Machine, StreamWorkload};
use mpfluid::h5lite::{codec, Attr, Backing, Dtype, H5File};
use mpfluid::stream::{EpochPublisher, PublisherOptions, StreamSubscriber};
use mpfluid::util::fmt_bytes;

fn tmp(name: &str) -> std::path::PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("stream_bench_{}_{}", std::process::id(), name));
    p
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[i]
}

fn sorted(mut v: Vec<f64>) -> Vec<f64> {
    v.sort_by(|a, b| a.total_cmp(b));
    v
}

/// Epoch-`k` payload for a `rows × elems` f32 dataset — cheap to generate
/// so the harness cost stays out of the measurements.
fn payload(k: u64, rows: u64, elems: usize) -> Vec<u8> {
    let v: Vec<f32> = (0..rows as usize * elems)
        .map(|i| (k as u32 ^ i as u32) as f32)
        .collect();
    codec::f32s_to_bytes(&v)
}

/// Paged-backed writer file with one contiguous `rows × elems` dataset.
fn make_file(path: &std::path::Path, rows: u64, elems: usize) -> H5File {
    let mut f = H5File::create_backed(path, 1, Backing::Paged).unwrap();
    f.create_dataset("/g", "field", Dtype::F32, &[rows, elems as u64])
        .unwrap();
    f.commit().unwrap();
    f
}

struct WriterLeg {
    commit_p50_ms: f64,
    commit_p99_ms: f64,
    write_seconds: f64,
    drain_seconds: f64,
    dropped: u64,
}

/// Run `epochs` commits with `subs` live subscribers attached, timing each
/// commit-return; then wait for every subscriber to drain to the last
/// epoch.
fn writer_leg(subs: usize, epochs: u64, rows: u64, elems: usize) -> WriterLeg {
    let src = tmp(&format!("w{subs}_src"));
    let mut f = make_file(&src, rows, elems);
    let publisher = if subs > 0 {
        let p = EpochPublisher::bind("127.0.0.1:0", PublisherOptions::default()).unwrap();
        p.attach(&f).unwrap();
        Some(p)
    } else {
        None
    };
    let mut mirrors = Vec::new();
    let mut followers = Vec::new();
    for i in 0..subs {
        let m = tmp(&format!("w{subs}_mir{i}"));
        followers.push(
            StreamSubscriber::connect(publisher.as_ref().unwrap().local_addr(), &src, &m)
                .unwrap(),
        );
        mirrors.push(m);
    }
    let ds = f.dataset("/g", "field").unwrap();
    let mut commit_ms = Vec::with_capacity(epochs as usize);
    let t_all = Instant::now();
    for k in 1..=epochs {
        let data = payload(k, rows, elems);
        f.write_rows(&ds, 0, &data).unwrap();
        f.ensure_group("/g").attrs.insert("epoch".into(), Attr::I64(k as i64));
        let t0 = Instant::now();
        f.commit().unwrap();
        commit_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let write_seconds = t_all.elapsed().as_secs_f64();
    let t_drain = Instant::now();
    for s in &followers {
        s.wait_for_epochs(epochs, Duration::from_secs(120)).unwrap();
    }
    let drain_seconds = t_drain.elapsed().as_secs_f64();
    let dropped = publisher.as_ref().map_or(0, |p| p.stats().dropped_batches);
    drop(followers);
    if let Some(p) = publisher {
        p.shutdown();
    }
    f.wait_durable().unwrap();
    drop(f);
    std::fs::remove_file(&src).ok();
    for m in mirrors {
        std::fs::remove_file(m).ok();
    }
    let commit_ms = sorted(commit_ms);
    WriterLeg {
        commit_p50_ms: percentile(&commit_ms, 0.5),
        commit_p99_ms: percentile(&commit_ms, 0.99),
        write_seconds,
        drain_seconds,
        dropped,
    }
}

/// Commit-to-visible latency per epoch: streamed (subscriber applies the
/// flip) vs. file polling (reopen the shared file every `poll` until the
/// epoch attribute shows up — which first needs the flusher to make the
/// epoch durable).
fn latency_leg(epochs: u64, rows: u64, elems: usize, poll: Duration) -> (Vec<f64>, Vec<f64>) {
    // streamed follower
    let src = tmp("lat_src");
    let mirror = tmp("lat_mir");
    let mut f = make_file(&src, rows, elems);
    let publisher = EpochPublisher::bind("127.0.0.1:0", PublisherOptions::default()).unwrap();
    publisher.attach(&f).unwrap();
    let sub = StreamSubscriber::connect(publisher.local_addr(), &src, &mirror).unwrap();
    let ds = f.dataset("/g", "field").unwrap();
    let mut stream_ms = Vec::with_capacity(epochs as usize);
    for k in 1..=epochs {
        let data = payload(k, rows, elems);
        f.write_rows(&ds, 0, &data).unwrap();
        f.ensure_group("/g").attrs.insert("epoch".into(), Attr::I64(k as i64));
        let t0 = Instant::now();
        f.commit().unwrap();
        sub.wait_for_epochs(k, Duration::from_secs(60)).unwrap();
        stream_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    drop(sub);
    publisher.shutdown();
    f.wait_durable().unwrap();
    drop(f);
    std::fs::remove_file(&src).ok();
    std::fs::remove_file(&mirror).ok();

    // file-polling baseline: same writer, no publisher; a "viewer" reopens
    // the shared file until the epoch lands (crash-consistent opens always
    // succeed and show the last durable epoch)
    let src = tmp("poll_src");
    let mut f = make_file(&src, rows, elems);
    let ds = f.dataset("/g", "field").unwrap();
    let mut poll_ms = Vec::with_capacity(epochs as usize);
    for k in 1..=epochs {
        let data = payload(k, rows, elems);
        f.write_rows(&ds, 0, &data).unwrap();
        f.ensure_group("/g").attrs.insert("epoch".into(), Attr::I64(k as i64));
        let t0 = Instant::now();
        f.commit().unwrap();
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            assert!(Instant::now() < deadline, "poll baseline never saw epoch {k}");
            let seen = H5File::open(&src).ok().and_then(|rf| {
                match rf.group("/g").ok()?.attrs.get("epoch") {
                    Some(Attr::I64(v)) => Some(*v as u64),
                    _ => None,
                }
            });
            if seen == Some(k) {
                break;
            }
            std::thread::sleep(poll);
        }
        poll_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    f.wait_durable().unwrap();
    drop(f);
    std::fs::remove_file(&src).ok();
    (sorted(stream_ms), sorted(poll_ms))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (rows, elems) = if quick { (256, 256) } else { (1024, 1024) };
    let epochs: u64 = if quick { 30 } else { 50 };
    let epoch_bytes = rows * elems as u64 * 4;
    let poll = Duration::from_millis(5);

    println!(
        "== stream_follow: {epochs} epochs x {} contiguous rewrites{} ==\n",
        fmt_bytes(epoch_bytes),
        if quick { " (quick)" } else { "" }
    );

    // -- writer slowdown vs. fan-out ------------------------------------
    println!("-- writer commit-return vs. subscriber count --");
    println!(
        "{:>6} {:>12} {:>12} {:>10} {:>10} {:>9}",
        "subs", "commit p50", "commit p99", "write s", "drain s", "dropped"
    );
    let fleet = [0usize, 1, 2, 4, 8];
    let mut legs = Vec::new();
    for &subs in &fleet {
        let leg = writer_leg(subs, epochs, rows, elems);
        println!(
            "{:>6} {:>9.3} ms {:>9.3} ms {:>10.3} {:>10.3} {:>9}",
            subs, leg.commit_p50_ms, leg.commit_p99_ms, leg.write_seconds, leg.drain_seconds,
            leg.dropped
        );
        legs.push((subs, leg));
    }

    // -- delivery latency vs. file polling ------------------------------
    let (stream_ms, poll_ms) = latency_leg(epochs, rows, elems, poll);
    println!("\n-- commit-to-visible latency ({}ms poll) --", poll.as_millis());
    println!("{:>18} {:>12} {:>12}", "", "p50", "p99");
    println!(
        "{:>18} {:>9.3} ms {:>9.3} ms",
        "streamed",
        percentile(&stream_ms, 0.5),
        percentile(&stream_ms, 0.99)
    );
    println!(
        "{:>18} {:>9.3} ms {:>9.3} ms",
        "file polling",
        percentile(&poll_ms, 0.5),
        percentile(&poll_ms, 0.99)
    );

    // -- machine-model cross-check --------------------------------------
    let est = Machine::local().estimate_stream(&StreamWorkload {
        subscribers: 8,
        epoch_bytes,
        ranks: 8,
        poll_interval: poll.as_secs_f64(),
    });
    println!(
        "\nmodel (local, 8 subs): stream {:.4}s vs file {:.4}s per epoch — {:.1}x",
        est.stream_seconds, est.file_seconds, est.speedup
    );

    if quick {
        // claim 1: tee + fan-out cost the writer's commit path ≤10%
        // (+0.25 ms scheduling-noise floor — commits are sub-millisecond
        // at the quick size)
        let base = legs.iter().find(|(s, _)| *s == 0).unwrap().1.commit_p50_ms;
        let eight = legs.iter().find(|(s, _)| *s == 8).unwrap().1.commit_p50_ms;
        if eight > base * 1.10 + 0.25 {
            eprintln!(
                "FAIL: commit p50 degraded {base:.3} -> {eight:.3} ms with 8 subscribers \
                 (>10% + noise floor)"
            );
            std::process::exit(1);
        }
        // claim 2: streamed delivery beats durability-wait + poll discovery
        let s50 = percentile(&stream_ms, 0.5);
        let p50 = percentile(&poll_ms, 0.5);
        if s50 >= p50 {
            eprintln!("FAIL: streamed p50 {s50:.3} ms not below polling p50 {p50:.3} ms");
            std::process::exit(1);
        }
        println!("\nquick smoke OK: commit p50 {base:.3} -> {eight:.3} ms, stream p50 {s50:.3} ms < poll p50 {p50:.3} ms");
    }
}
