//! Bench: **hot-path microbenchmarks** for the perf pass (EXPERIMENTS.md
//! §Perf) — per-stage timings of everything on the request path:
//!
//! * compute kernels per backend (rust oracle vs PJRT artifacts) and batch
//!   size — quantifies dispatch amortisation;
//! * batcher pack/scatter;
//! * snapshot pack + collective write phases;
//! * one full coordinator step, broken down.
//!
//! Run: `cargo bench --bench hotpath`

use mpfluid::config::Scenario;
use mpfluid::h5lite::codec::{self, encode_chunk_adaptive, Codec, ALL_CODECS};
use mpfluid::h5lite::H5File;
use mpfluid::iokernel;
use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::pario::ParallelIo;
use mpfluid::physics::{ComputeBackend, Params, RustBackend};
use mpfluid::runtime::PjrtBackend;
use mpfluid::util::bench::measure;
use mpfluid::util::rng::Rng;
use mpfluid::util::synth::{smooth_field, turbulent_field, TURB_SEED};
use mpfluid::DGRID_N;

const PAD: usize = (DGRID_N + 2) * (DGRID_N + 2) * (DGRID_N + 2);
const INT: usize = DGRID_N * DGRID_N * DGRID_N;

fn kernel_sweep(name: &str, be: &dyn ComputeBackend) {
    println!("== {name}: jacobi sweep cost vs batch size ==");
    println!(
        "{:>8} {:>22} {:>16} {:>14}",
        "batch", "wall-clock", "per-grid", "cells/s"
    );
    let par = Params::isothermal(0.01, 0.05, 0.01);
    let mut rng = Rng::new(5);
    for b in [1usize, 8, 32, 128, 512] {
        let mut p = vec![0.0f32; b * PAD];
        let mut rhs = vec![0.0f32; b * INT];
        rng.fill_f32(&mut p, -1.0, 1.0);
        rng.fill_f32(&mut rhs, -1.0, 1.0);
        let mut out = vec![0.0f32; b * INT];
        let iters = if b >= 128 { 10 } else { 30 };
        let s = measure(iters, || {
            be.jacobi(b, &p, &rhs, &par, &mut out);
        });
        println!(
            "{:>8} {:>22} {:>13.1} µs {:>13.2e}",
            b,
            s.fmt_ms(),
            s.min * 1e6 / b as f64,
            (b * INT) as f64 / s.min
        );
    }
}

fn predictor_sweep(name: &str, be: &dyn ComputeBackend) {
    println!("\n== {name}: fused predictor cost vs batch size ==");
    let par = Params {
        dt: 0.01,
        h: 0.05,
        nu: 0.01,
        alpha: 0.01,
        beta_g: 0.3,
        t_inf: 300.0,
        q_int: 0.0,
        rho: 1.0,
        omega: 1.0,
    };
    let mut rng = Rng::new(6);
    for b in [1usize, 32, 256] {
        let mut fields = vec![vec![0.0f32; b * PAD]; 4];
        for f in fields.iter_mut() {
            rng.fill_f32(f, -1.0, 1.0);
        }
        let mut outs = vec![vec![0.0f32; b * INT]; 4];
        let s = measure(10, || {
            let [u, v, w, t] = &fields[..] else { unreachable!() };
            let [uo, vo, wo, to] = &mut outs[..] else { unreachable!() };
            be.predictor(b, u, v, w, t, &par, uo, vo, wo, to);
        });
        println!("  batch {b:>4}: {}  ({:.1} µs/grid)", s.fmt_ms(), s.min * 1e6 / b as f64);
    }
}

fn step_breakdown() {
    println!("\n== full coordinator step, depth 2 (585 grids, 64 leaves… 512 leaves) ==");
    let sc = Scenario::channel(2);
    let mut sim = sc.build();
    sim.step(&RustBackend); // warm state
    let s = measure(5, || {
        sim.step(&RustBackend);
    });
    println!("  rust backend: {}", s.fmt_ms());
    if let Ok(pjrt) = PjrtBackend::load_default() {
        let mut sim2 = sc.build();
        sim2.step(&pjrt);
        let s2 = measure(3, || {
            sim2.step(&pjrt);
        });
        println!("  pjrt backend: {}", s2.fmt_ms());
    }
}

/// Per-stage codec v2 throughput on one 128 KiB chunk (the write path's
/// unit of codec work): encode and decode MB/s per pipeline, smooth vs
/// turbulent input, plus the adaptive selector end-to-end.
fn codec_stage_sweep() {
    println!("\n== codec v2 stages: encode/decode throughput per 128 KiB chunk ==");
    println!(
        "{:>10} {:>22} {:>8} {:>12} {:>12}",
        "field", "codec", "ratio", "enc MB/s", "dec MB/s"
    );
    let fields: [(&str, Vec<f32>); 2] = [
        ("smooth", smooth_field(32768)),
        ("turbulent", turbulent_field(32768, TURB_SEED)),
    ];
    for (fname, field) in &fields {
        let raw = codec::f32s_to_bytes(field);
        for c in ALL_CODECS {
            if c == Codec::Raw {
                continue;
            }
            let enc = c.encode(&raw, 4);
            let t_enc = measure(3, || {
                std::hint::black_box(c.encode(&raw, 4));
            })
            .min;
            let t_dec = measure(3, || {
                std::hint::black_box(c.decode(&enc, 4, raw.len()).unwrap());
            })
            .min;
            println!(
                "{:>10} {:>22} {:>7.3} {:>12.0} {:>12.0}",
                fname,
                c.name(),
                enc.len() as f64 / raw.len() as f64,
                raw.len() as f64 / t_enc / 1e6,
                raw.len() as f64 / t_dec / 1e6,
            );
        }
        let t_ad = measure(3, || {
            std::hint::black_box(encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &raw, 4));
        })
        .min;
        let pick = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &raw, 4);
        println!(
            "{:>10} {:>22} {:>7.3} {:>12.0} {:>12}",
            fname,
            "adaptive",
            pick.stored_or(&raw).len() as f64 / raw.len() as f64,
            raw.len() as f64 / t_ad / 1e6,
            format!("pick={}", pick.codec.map_or("store", |c| c.name())),
        );
    }
}

fn io_breakdown() {
    println!("\n== snapshot write path breakdown (depth 2, 16 ranks) ==");
    let mut sc = Scenario::channel(2);
    sc.ranks = 16;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
    let dir = std::env::temp_dir();
    let mut n = 0u32;
    let mut pack_s = 0.0;
    let mut real_s = 0.0;
    let mut bytes = 0u64;
    let s = measure(5, || {
        let path = dir.join(format!("hot_io_{n}.h5"));
        n += 1;
        let mut f = H5File::create(&path, 4096).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 16).unwrap();
        let rep =
            iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
                .unwrap();
        pack_s = rep.pack_seconds;
        real_s = rep.io.real_seconds;
        bytes = rep.io.bytes;
        std::fs::remove_file(&path).ok();
    });
    println!(
        "  total {}  = pack {:.1} ms + pwrite {:.1} ms   ({} payload)",
        s.fmt_ms(),
        pack_s * 1e3,
        real_s * 1e3,
        mpfluid::util::fmt_bytes(bytes)
    );

    // the paged backend's stage split: what the caller blocks on
    // (commit-return) vs what the flusher thread does in the background
    use mpfluid::h5lite::Backing;
    use mpfluid::iokernel::SnapshotOptions;
    let mut commit_s = 0.0;
    let mut drain_s = 0.0;
    let mut flush_busy = 0.0;
    let s2 = measure(5, || {
        let path = dir.join(format!("hot_io_paged_{n}.h5"));
        n += 1;
        let mut f = H5File::create_backed(&path, 4096, Backing::Paged).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 16).unwrap();
        let t0 = std::time::Instant::now();
        iokernel::write_snapshot_with(
            &mut f,
            &io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            0.0,
            &SnapshotOptions::paged(),
        )
        .unwrap();
        commit_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        f.wait_durable().unwrap();
        drain_s = t1.elapsed().as_secs_f64();
        flush_busy = f.flush_stats().busy_seconds;
        drop(f);
        std::fs::remove_file(&path).ok();
    });
    println!(
        "  paged {}  = commit-return {:.1} ms + drain {:.1} ms   (flusher busy {:.1} ms)",
        s2.fmt_ms(),
        commit_s * 1e3,
        drain_s * 1e3,
        flush_busy * 1e3
    );
}

fn main() {
    kernel_sweep("rust oracle", &RustBackend);
    predictor_sweep("rust oracle", &RustBackend);
    match PjrtBackend::load_default() {
        Ok(pjrt) => {
            kernel_sweep("pjrt artifacts", &pjrt);
            predictor_sweep("pjrt artifacts", &pjrt);
        }
        Err(e) => println!("\n(pjrt skipped: {e})"),
    }
    step_breakdown();
    codec_stage_sweep();
    io_breakdown();
}
