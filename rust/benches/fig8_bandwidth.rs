//! Bench: **Fig 8a + Fig 8b** — sustained checkpoint write bandwidth.
//!
//! Four parts:
//! 1. *Real* collective writes of miniature snapshots through the full
//!    iokernel → pario → h5lite stack on this host, sweeping rank counts
//!    (measures the actual software path: pack, aggregate, merge, pwrite).
//! 2. Raw vs chunk-compressed storage at equal logical bytes: effective
//!    bandwidth (raw bytes / wall-clock) and the stored-byte ratio of the
//!    v2 shuffle/delta/LZ cell-data path.
//! 3. Steering rewrites: file-size amplification of N full cell-data
//!    rewrites — the v2 leak vs the v2.1 free-space manager vs `repack()`.
//! 4. The calibrated machine model priced at the paper's scales — the
//!    series of Fig 8a (337 GB), Fig 8b (2.7 TB) and VPIC-IO alongside,
//!    with the compressed-write multiplier.
//!
//! Run: `cargo bench --bench fig8_bandwidth`

use mpfluid::cluster::{
    paper_depth6_workload, paper_depth7_workload, IoTuning, Machine,
};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, SnapshotOptions};
use mpfluid::pario::ParallelIo;
use mpfluid::util::{bench::measure, fmt_bytes, fmt_gbps};
use mpfluid::vpic;

fn real_write_sweep() {
    println!("== real shared-file checkpoint writes (depth-2 domain, this host) ==");
    println!(
        "{:>8} {:>12} {:>10} {:>16} {:>12}",
        "ranks", "bytes", "ops", "time", "bandwidth"
    );
    for ranks in [1u64, 4, 16, 64] {
        let mut sc = Scenario::channel(2);
        sc.ranks = ranks as u32;
        let sim = sc.build();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), ranks);
        let dir = std::env::temp_dir();
        let mut n = 0u32;
        let mut bytes = 0u64;
        let mut ops = 0u64;
        let sample = measure(5, || {
            let path = dir.join(format!("fig8_real_{ranks}_{n}.h5"));
            n += 1;
            let mut f = H5File::create(&path, 4096).unwrap();
            iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, ranks).unwrap();
            let rep =
                iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
                    .unwrap();
            bytes = rep.io.bytes;
            ops = rep.io.write_ops;
            std::fs::remove_file(&path).ok();
        });
        println!(
            "{:>8} {:>12} {:>10} {:>16} {:>12}",
            ranks,
            fmt_bytes(bytes),
            ops,
            sample.fmt_ms(),
            fmt_gbps(bytes as f64, sample.min)
        );
    }
}

/// Raw vs chunk-compressed snapshots at equal logical bytes (this host):
/// the acceptance signal is *effective* bandwidth — raw payload bytes over
/// wall-clock — where the compressed path wins as soon as the codec
/// outruns the storage device on compressible cell data. The real writes
/// use matching rank counts (a mismatched `n_ranks` would skew the
/// rank→aggregator mapping and measure threading, not the codec); the
/// measured stored/raw ratio is then priced at JuQueen scale and returned
/// so the Fig 8a table uses the measurement, not a frozen constant.
fn real_compression_comparison() -> f64 {
    println!("\n== raw vs chunked+compressed snapshot (depth-2 domain, this host) ==");
    println!(
        "{:>12} {:>12} {:>12} {:>8} {:>14}",
        "layout", "raw bytes", "stored", "ratio", "eff real"
    );
    let mut sc = Scenario::channel(2);
    sc.ranks = 16;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
    let dir = std::env::temp_dir();
    let mut measured_ratio = 1.0f64;
    for (label, opts) in [
        ("contiguous", SnapshotOptions::uncompressed()),
        ("chunked+lz", SnapshotOptions::default()),
    ] {
        let path = dir.join(format!("fig8_cmp_{}_{label}.h5", std::process::id()));
        let mut f = H5File::create(&path, 4096).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 16).unwrap();
        let rep = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            0.0,
            &opts,
        )
        .unwrap();
        if rep.io.stored_bytes < rep.io.bytes {
            measured_ratio = rep.io.stored_bytes as f64 / rep.io.bytes as f64;
        }
        println!(
            "{:>12} {:>12} {:>12} {:>7.2}x {:>14}",
            label,
            fmt_bytes(rep.io.bytes),
            fmt_bytes(rep.io.stored_bytes),
            rep.io.bytes as f64 / rep.io.stored_bytes.max(1) as f64,
            fmt_gbps(rep.io.bytes as f64, rep.io.real_seconds),
        );
        std::fs::remove_file(&path).ok();
    }
    // the measured ratio, priced at the paper's scale
    let m = Machine::juqueen();
    let w = paper_depth6_workload(8192);
    let raw = m.estimate_write(&w, &IoTuning::default());
    let comp = m.estimate_write_compressed(
        &w,
        &IoTuning::default(),
        (w.total_bytes as f64 * measured_ratio) as u64,
    );
    println!(
        "  JuQueen model @8192 ranks, measured ratio {:.2}x: raw {:.2} GB/s → compressed {:.2} GB/s",
        1.0 / measured_ratio,
        raw.bandwidth / 1e9,
        comp.bandwidth / 1e9
    );
    measured_ratio
}

/// Steering rewrites: write one snapshot, then rewrite all of its cell
/// data N times (the long-running interactive scenario). A v2 file leaks
/// every abandoned extent and grows ~N×; a v2.1 file recycles them through
/// the free-space manager and stays near the single-write size; `repack()`
/// then compacts either to the fragmentation-free minimum.
fn rewrite_amplification() {
    use mpfluid::h5lite::{ReusePolicy, FORMAT_V2, FORMAT_V21};
    use mpfluid::iokernel::rewrite_snapshot_cells;
    use mpfluid::{var, DGRID_CELLS};
    const N: u32 = 6;
    println!("\n== steering rewrites ×{N}: file-size amplification (this host) ==");
    println!(
        "{:>14} {:>12} {:>12} {:>8} {:>12}",
        "format", "single", "rewritten", "amplif", "repacked"
    );
    for (label, version) in [("v2 (leak)", FORMAT_V2), ("v2.1 (reuse)", FORMAT_V21)] {
        let mut sc = Scenario::channel(2);
        sc.ranks = 8;
        let mut sim = sc.build();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let path = std::env::temp_dir().join(format!(
            "fig8_amp_{}_{version}.h5",
            std::process::id()
        ));
        let mut f = H5File::create_versioned(&path, 4096, version).unwrap();
        f.set_reuse_policy(ReusePolicy::Immediate);
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 8).unwrap();
        iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
            .unwrap();
        let single = std::fs::metadata(&path).unwrap().len();
        for step in 0..N {
            for g in sim.grids.iter_mut() {
                let data = vec![step as f32; DGRID_CELLS];
                g.cur.set_interior(var::P, &data);
            }
            rewrite_snapshot_cells(
                &mut f,
                &io,
                &sim.nbs.tree,
                &sim.part,
                &sim.grids,
                0.0,
                &SnapshotOptions::default(),
            )
            .unwrap();
        }
        let grown = std::fs::metadata(&path).unwrap().len();
        f.repack().unwrap();
        let repacked = std::fs::metadata(&path).unwrap().len();
        println!(
            "{:>14} {:>12} {:>12} {:>7.2}x {:>12}",
            label,
            fmt_bytes(single),
            fmt_bytes(grown),
            grown as f64 / single as f64,
            fmt_bytes(repacked),
        );
        std::fs::remove_file(&path).ok();
    }
}

/// `lz_ratio` is the stored/raw ratio of the shuffle/delta/LZ cell-data
/// path, measured on real channel-flow snapshots by
/// [`real_compression_comparison`].
fn modelled_fig8a(lz_ratio: f64) {
    println!("\n== Fig 8a (model): JuQueen, 1024³, 337 GB/checkpoint ==");
    println!(
        "{:>10} {:>16} {:>16} {:>18}",
        "ranks", "mpfluid GB/s", "VPIC-IO GB/s", "mpfluid+lz GB/s"
    );
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [2048u64, 4096, 8192, 16384, 32768] {
        let w = paper_depth6_workload(ranks);
        let mp = m.estimate_write(&w, &t);
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        let lz = m.estimate_write_compressed(
            &w,
            &t,
            (w.total_bytes as f64 * lz_ratio) as u64,
        );
        println!(
            "{:>10} {:>16.2} {:>16.2} {:>18.2}",
            ranks,
            mp.bandwidth / 1e9,
            vp / 1e9,
            lz.bandwidth / 1e9
        );
    }
}

fn modelled_fig8b() {
    println!("\n== Fig 8b (model): JuQueen, 2048³, 2.7 TB/checkpoint ==");
    println!(
        "{:>10} {:>16} {:>16}",
        "ranks", "mpfluid GB/s", "VPIC-IO GB/s"
    );
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [8192u64, 16384, 32768] {
        let w = paper_depth7_workload(ranks);
        let mp = m.estimate_write(&w, &t);
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        println!(
            "{:>10} {:>16.2} {:>16.2}",
            ranks,
            mp.bandwidth / 1e9,
            vp / 1e9
        );
    }
}

fn modelled_supermuc() {
    println!("\n== §5.3 (model): SuperMUC, 1024³, 337 GB/checkpoint ==");
    println!("{:>10} {:>16} {:>12}", "ranks", "model GB/s", "paper GB/s");
    let m = Machine::supermuc();
    for (ranks, paper) in [(2048u64, 21.4), (4096, 14.92), (8192, 4.64)] {
        let e = m.estimate_write(&paper_depth6_workload(ranks), &IoTuning::default());
        println!("{:>10} {:>16.2} {:>12.2}", ranks, e.bandwidth / 1e9, paper);
    }
}

fn real_vpic_write() {
    println!("\n== real VPIC-IO dump vs mpfluid snapshot at equal bytes (this host) ==");
    let mut sc = Scenario::channel(2);
    sc.ranks = 16;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
    let dir = std::env::temp_dir();
    // mpfluid
    let mp_path = dir.join("fig8_mp.h5");
    let mut f = H5File::create(&mp_path, 4096).unwrap();
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 16).unwrap();
    let rep =
        iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0).unwrap();
    // VPIC at the same byte volume
    let vp_path = dir.join("fig8_vp.h5");
    let mut vf = H5File::create(&vp_path, 4096).unwrap();
    let vrep = vpic::write_dump(&mut vf, &io, vpic::particles_for_bytes(rep.io.bytes), 1).unwrap();
    println!(
        "  mpfluid: {} in {:.1} ms → {}",
        fmt_bytes(rep.io.bytes),
        rep.io.real_seconds * 1e3,
        fmt_gbps(rep.io.bytes as f64, rep.io.real_seconds)
    );
    println!(
        "  VPIC-IO: {} in {:.1} ms → {}",
        fmt_bytes(vrep.io.bytes),
        vrep.io.real_seconds * 1e3,
        fmt_gbps(vrep.io.bytes as f64, vrep.io.real_seconds)
    );
    std::fs::remove_file(&mp_path).ok();
    std::fs::remove_file(&vp_path).ok();
}

fn main() {
    real_write_sweep();
    let lz_ratio = real_compression_comparison();
    rewrite_amplification();
    real_vpic_write();
    modelled_fig8a(lz_ratio);
    modelled_fig8b();
    modelled_supermuc();
}
