//! Bench: **Fig 8a + Fig 8b** — sustained checkpoint write bandwidth.
//!
//! Two parts:
//! 1. *Real* collective writes of miniature snapshots through the full
//!    iokernel → pario → h5lite stack on this host, sweeping rank counts
//!    (measures the actual software path: pack, aggregate, merge, pwrite).
//! 2. The calibrated machine model priced at the paper's scales — the
//!    series of Fig 8a (337 GB), Fig 8b (2.7 TB) and VPIC-IO alongside.
//!
//! Run: `cargo bench --bench fig8_bandwidth`

use mpfluid::cluster::{
    paper_depth6_workload, paper_depth7_workload, IoTuning, Machine,
};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel;
use mpfluid::pario::ParallelIo;
use mpfluid::util::{bench::measure, fmt_bytes, fmt_gbps};
use mpfluid::vpic;

fn real_write_sweep() {
    println!("== real shared-file checkpoint writes (depth-2 domain, this host) ==");
    println!(
        "{:>8} {:>12} {:>10} {:>16} {:>12}",
        "ranks", "bytes", "ops", "time", "bandwidth"
    );
    for ranks in [1u64, 4, 16, 64] {
        let mut sc = Scenario::channel(2);
        sc.ranks = ranks as u32;
        let sim = sc.build();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), ranks);
        let dir = std::env::temp_dir();
        let mut n = 0u32;
        let mut bytes = 0u64;
        let mut ops = 0u64;
        let sample = measure(5, || {
            let path = dir.join(format!("fig8_real_{ranks}_{n}.h5"));
            n += 1;
            let mut f = H5File::create(&path, 4096).unwrap();
            iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, ranks).unwrap();
            let rep =
                iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
                    .unwrap();
            bytes = rep.io.bytes;
            ops = rep.io.write_ops;
            std::fs::remove_file(&path).ok();
        });
        println!(
            "{:>8} {:>12} {:>10} {:>16} {:>12}",
            ranks,
            fmt_bytes(bytes),
            ops,
            sample.fmt_ms(),
            fmt_gbps(bytes as f64, sample.min)
        );
    }
}

fn modelled_fig8a() {
    println!("\n== Fig 8a (model): JuQueen, 1024³, 337 GB/checkpoint ==");
    println!(
        "{:>10} {:>16} {:>16}",
        "ranks", "mpfluid GB/s", "VPIC-IO GB/s"
    );
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [2048u64, 4096, 8192, 16384, 32768] {
        let w = paper_depth6_workload(ranks);
        let mp = m.estimate_write(&w, &t);
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        println!(
            "{:>10} {:>16.2} {:>16.2}",
            ranks,
            mp.bandwidth / 1e9,
            vp / 1e9
        );
    }
}

fn modelled_fig8b() {
    println!("\n== Fig 8b (model): JuQueen, 2048³, 2.7 TB/checkpoint ==");
    println!(
        "{:>10} {:>16} {:>16}",
        "ranks", "mpfluid GB/s", "VPIC-IO GB/s"
    );
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [8192u64, 16384, 32768] {
        let w = paper_depth7_workload(ranks);
        let mp = m.estimate_write(&w, &t);
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        println!(
            "{:>10} {:>16.2} {:>16.2}",
            ranks,
            mp.bandwidth / 1e9,
            vp / 1e9
        );
    }
}

fn modelled_supermuc() {
    println!("\n== §5.3 (model): SuperMUC, 1024³, 337 GB/checkpoint ==");
    println!("{:>10} {:>16} {:>12}", "ranks", "model GB/s", "paper GB/s");
    let m = Machine::supermuc();
    for (ranks, paper) in [(2048u64, 21.4), (4096, 14.92), (8192, 4.64)] {
        let e = m.estimate_write(&paper_depth6_workload(ranks), &IoTuning::default());
        println!("{:>10} {:>16.2} {:>12.2}", ranks, e.bandwidth / 1e9, paper);
    }
}

fn real_vpic_write() {
    println!("\n== real VPIC-IO dump vs mpfluid snapshot at equal bytes (this host) ==");
    let mut sc = Scenario::channel(2);
    sc.ranks = 16;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
    let dir = std::env::temp_dir();
    // mpfluid
    let mp_path = dir.join("fig8_mp.h5");
    let mut f = H5File::create(&mp_path, 4096).unwrap();
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 16).unwrap();
    let rep =
        iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0).unwrap();
    // VPIC at the same byte volume
    let vp_path = dir.join("fig8_vp.h5");
    let mut vf = H5File::create(&vp_path, 4096).unwrap();
    let vrep = vpic::write_dump(&mut vf, &io, vpic::particles_for_bytes(rep.io.bytes), 1).unwrap();
    println!(
        "  mpfluid: {} in {:.1} ms → {}",
        fmt_bytes(rep.io.bytes),
        rep.io.real_seconds * 1e3,
        fmt_gbps(rep.io.bytes as f64, rep.io.real_seconds)
    );
    println!(
        "  VPIC-IO: {} in {:.1} ms → {}",
        fmt_bytes(vrep.io.bytes),
        vrep.io.real_seconds * 1e3,
        fmt_gbps(vrep.io.bytes as f64, vrep.io.real_seconds)
    );
    std::fs::remove_file(&mp_path).ok();
    std::fs::remove_file(&vp_path).ok();
}

fn main() {
    real_write_sweep();
    real_vpic_write();
    modelled_fig8a();
    modelled_fig8b();
    modelled_supermuc();
}
