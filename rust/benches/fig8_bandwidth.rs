//! Bench: **Fig 8a + Fig 8b** — sustained checkpoint write bandwidth.
//!
//! Five parts:
//! 1. *Real* collective writes of miniature snapshots through the full
//!    iokernel → pario → h5lite stack on this host, sweeping rank counts
//!    (measures the actual software path: pack, aggregate, merge, pwrite).
//! 2. **Codec v2 table**: stored-byte ratio, encode throughput and
//!    modelled effective bandwidth per codec on smooth vs turbulent
//!    synthetic f32 fields — including the PR-1 single-candidate LZ
//!    baseline, both entropy backends (range coder and tANS) and the
//!    adaptive per-chunk selector, with the ratio-improvement and
//!    compress-time multiples the codec-v2 acceptance criteria name,
//!    plus the tANS-vs-rc encode/decode throughput comparison the PR-9
//!    acceptance criteria name (asserted in the `--quick` CI leg).
//! 3. Raw vs chunk-compressed storage at equal logical bytes: effective
//!    bandwidth (raw bytes / wall-clock) and the stored-byte ratio of the
//!    v2 adaptive cell-data path.
//! 4. Steering rewrites: file-size amplification of N full cell-data
//!    rewrites — the v2 leak vs the v2.1 free-space manager vs `repack()`.
//! 5. The calibrated machine model priced at the paper's scales — the
//!    series of Fig 8a (337 GB), Fig 8b (2.7 TB) and VPIC-IO alongside,
//!    with the compressed-write multiplier per codec.
//!
//! Run: `cargo bench --bench fig8_bandwidth` (`-- --quick` for the
//! CI bench-bitrot leg: codec table + model tables only).

use mpfluid::cluster::{
    paper_depth6_workload, paper_depth7_workload, IoTuning, Machine,
};
use mpfluid::config::Scenario;
use mpfluid::h5lite::codec::{
    self, encode_chunk_adaptive, lz_compress, Codec,
};
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, SnapshotOptions};
use mpfluid::pario::ParallelIo;
use mpfluid::util::synth::{smooth_field, turbulent_field, TURB_SEED};
use mpfluid::util::{bench::measure, fmt_bytes, fmt_gbps};
use mpfluid::vpic;

fn real_write_sweep() {
    println!("== real shared-file checkpoint writes (depth-2 domain, this host) ==");
    println!(
        "{:>8} {:>12} {:>10} {:>16} {:>12}",
        "ranks", "bytes", "ops", "time", "bandwidth"
    );
    for ranks in [1u64, 4, 16, 64] {
        let mut sc = Scenario::channel(2);
        sc.ranks = ranks as u32;
        let sim = sc.build();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), ranks);
        let dir = std::env::temp_dir();
        let mut n = 0u32;
        let mut bytes = 0u64;
        let mut ops = 0u64;
        let sample = measure(5, || {
            let path = dir.join(format!("fig8_real_{ranks}_{n}.h5"));
            n += 1;
            let mut f = H5File::create(&path, 4096).unwrap();
            iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, ranks).unwrap();
            let rep =
                iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
                    .unwrap();
            bytes = rep.io.bytes;
            ops = rep.io.write_ops;
            std::fs::remove_file(&path).ok();
        });
        println!(
            "{:>8} {:>12} {:>10} {:>16} {:>12}",
            ranks,
            fmt_bytes(bytes),
            ops,
            sample.fmt_ms(),
            fmt_gbps(bytes as f64, sample.min)
        );
    }
}

/// Codec v2 acceptance table: every pipeline on the canonical smooth /
/// turbulent / noise chunks (8192 f32 = 32 KiB — one aggregator-sized
/// chunk). Reports the stored ratio, the measured encode throughput, the
/// compress-time multiple vs the PR-1 single-candidate LZ, and the
/// JuQueen-modelled effective bandwidth at the measured ratio.
fn codec_v2_table(iters: u32) {
    println!("\n== codec v2: per-codec ratio + effective bandwidth (32 KiB f32 chunks) ==");
    println!(
        "{:>10} {:>22} {:>9} {:>7} {:>10} {:>8} {:>14}",
        "field", "codec", "stored", "ratio", "enc MB/s", "t/t_lz1", "model GB/s eff"
    );
    let m = Machine::juqueen();
    let t = IoTuning::default();
    let w = paper_depth6_workload(8192);
    let fields: [(&str, Vec<f32>); 2] = [
        ("smooth", smooth_field(8192)),
        ("turbulent", turbulent_field(8192, TURB_SEED)),
    ];
    for (fname, field) in &fields {
        let raw = codec::f32s_to_bytes(field);
        // the PR-1 baseline: shuffle + delta + single-candidate LZ
        let baseline = || {
            let mut f = codec::shuffle(&raw, 4);
            codec::delta_encode(&mut f);
            lz_compress(&f)
        };
        let t_lz1 = measure(iters, || {
            std::hint::black_box(baseline());
        })
        .min;
        let lz1_len = baseline().len().min(raw.len());
        let mut adaptive_ratio_imp = 0.0;
        let mut adaptive_time_mult = 0.0;
        // what the adaptive selector actually picks on this field — also
        // the codec class the model prices for the adaptive row
        let adaptive_codec = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &raw, 4)
            .codec
            .unwrap_or(Codec::SHUFFLE_DELTA_LZ);
        let entries: [(&str, Box<dyn Fn() -> usize + '_>); 5] = [
            ("lz1 (single-cand)", Box::new(|| lz1_len)),
            (
                "chain LZ",
                Box::new(|| Codec::SHUFFLE_DELTA_LZ.encode(&raw, 4).len().min(raw.len())),
            ),
            (
                "chain LZ + rc",
                Box::new(|| {
                    Codec::SHUFFLE_DELTA_LZ_RC
                        .encode(&raw, 4)
                        .len()
                        .min(raw.len())
                }),
            ),
            (
                "chain LZ + tANS",
                Box::new(|| {
                    Codec::SHUFFLE_DELTA_LZ_TANS
                        .encode(&raw, 4)
                        .len()
                        .min(raw.len())
                }),
            ),
            (
                "adaptive",
                Box::new(|| {
                    let e = encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &raw, 4);
                    e.stored_or(&raw).len()
                }),
            ),
        ];
        for (cname, stored_of) in &entries {
            let t_enc = if *cname == "lz1 (single-cand)" {
                t_lz1
            } else {
                measure(iters, || {
                    std::hint::black_box(stored_of());
                })
                .min
            };
            let stored = stored_of();
            let ratio = stored as f64 / raw.len() as f64;
            // model codec class: each entropy row prices its own entry
            let model_codec = if cname.contains("tANS") {
                Codec::SHUFFLE_DELTA_LZ_TANS
            } else if cname.contains("rc") {
                Codec::SHUFFLE_DELTA_LZ_RC
            } else if *cname == "adaptive" {
                adaptive_codec
            } else {
                Codec::SHUFFLE_DELTA_LZ
            };
            let eff = if stored < raw.len() {
                m.estimate_write_compressed(
                    &w,
                    &t,
                    (w.total_bytes as f64 * ratio) as u64,
                    model_codec,
                )
                .bandwidth
            } else {
                m.estimate_write(&w, &t).bandwidth
            };
            if *cname == "adaptive" {
                adaptive_ratio_imp = lz1_len as f64 / stored as f64;
                adaptive_time_mult = t_enc / t_lz1;
            }
            println!(
                "{:>10} {:>22} {:>9} {:>6.3} {:>10.0} {:>7.2}x {:>14.2}",
                fname,
                cname,
                fmt_bytes(stored as u64),
                ratio,
                raw.len() as f64 / t_enc / 1e6,
                t_enc / t_lz1,
                eff / 1e9,
            );
        }
        println!(
            "  {fname}: adaptive vs single-candidate LZ — stored-ratio improvement \
             {adaptive_ratio_imp:.3}x (target ≥ 1.15 on turbulent), compress-time \
             {adaptive_time_mult:.2}x (target ≤ 1.5x)"
        );
    }
    // the Store fallback: pure noise must cost (almost) nothing extra
    let noise = mpfluid::util::synth::noise_bytes(7, 32768);
    let t_noise = measure(iters, || {
        std::hint::black_box(encode_chunk_adaptive(Codec::SHUFFLE_DELTA_LZ, &noise, 4).stored.is_none());
    })
    .min;
    println!(
        "  noise: adaptive → Store (raw), selection cost {:.0} MB/s",
        noise.len() as f64 / t_noise / 1e6
    );
}

/// tANS vs range coder on the canonical turbulent field: the PR-9
/// acceptance numbers — tANS decode ≥ 2× the coder's decode throughput
/// and encode no slower, at ≤ 3 % stored-ratio give-back. `assert_ci`
/// turns the printed comparison into hard assertions (the `--quick`
/// bench-bitrot leg), so a regression in either backend fails CI instead
/// of silently skewing a table nobody reads.
fn tans_vs_rc_throughput(iters: u32, assert_ci: bool) {
    println!("\n== tANS vs range coder (canonical turbulent field, 32 KiB f32) ==");
    let raw = codec::f32s_to_bytes(&turbulent_field(8192, TURB_SEED));
    let mut report = |codec: Codec| {
        let stored = codec.encode(&raw, 4);
        let t_enc = measure(iters, || {
            std::hint::black_box(codec.encode(&raw, 4).len());
        })
        .min;
        let t_dec = measure(iters, || {
            std::hint::black_box(codec.decode(&stored, 4, raw.len()).unwrap().len());
        })
        .min;
        println!(
            "{:>26} {:>9} ratio {:>5.3}  enc {:>7.0} MB/s  dec {:>7.0} MB/s",
            codec.name(),
            fmt_bytes(stored.len() as u64),
            stored.len() as f64 / raw.len() as f64,
            raw.len() as f64 / t_enc / 1e6,
            raw.len() as f64 / t_dec / 1e6,
        );
        (stored.len(), t_enc, t_dec)
    };
    let (rc_len, rc_enc, rc_dec) = report(Codec::SHUFFLE_DELTA_LZ_RC);
    let (tans_len, tans_enc, tans_dec) = report(Codec::SHUFFLE_DELTA_LZ_TANS);
    let dec_speedup = rc_dec / tans_dec;
    let enc_speedup = rc_enc / tans_enc;
    let give_back = tans_len as f64 / rc_len as f64 - 1.0;
    println!(
        "  tANS vs rc: decode {dec_speedup:.2}x (target ≥ 2x), encode {enc_speedup:.2}x \
         (target ≥ 1x), stored-ratio give-back {:.2}% (target ≤ 3%)",
        give_back * 100.0
    );
    if assert_ci {
        assert!(
            dec_speedup >= 2.0,
            "tANS decode {dec_speedup:.2}x rc — acceptance needs ≥ 2x"
        );
        assert!(
            enc_speedup >= 1.0,
            "tANS encode {enc_speedup:.2}x rc — acceptance needs no slower"
        );
        assert!(
            give_back <= 0.03,
            "tANS stored-ratio give-back {:.2}% — acceptance needs ≤ 3%",
            give_back * 100.0
        );
    }
}

/// Raw vs chunk-compressed snapshots at equal logical bytes (this host):
/// the acceptance signal is *effective* bandwidth — raw payload bytes over
/// wall-clock — where the compressed path wins as soon as the codec
/// outruns the storage device on compressible cell data. The real writes
/// use matching rank counts (a mismatched `n_ranks` would skew the
/// rank→aggregator mapping and measure threading, not the codec); the
/// measured stored/raw ratio and the dominant codec class are then priced
/// at JuQueen scale and returned so the Fig 8a table uses the
/// measurement, not a frozen constant.
fn real_compression_comparison() -> (f64, Codec) {
    println!("\n== raw vs chunked+compressed snapshot (depth-2 domain, this host) ==");
    println!(
        "{:>12} {:>12} {:>12} {:>8} {:>14} {:>18}",
        "layout", "raw bytes", "stored", "ratio", "eff real", "chunks s/l/rc/t"
    );
    let mut sc = Scenario::channel(2);
    sc.ranks = 16;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
    let dir = std::env::temp_dir();
    let mut measured_ratio = 1.0f64;
    let mut measured_codec = Codec::SHUFFLE_DELTA_LZ;
    for (label, opts) in [
        ("contiguous", SnapshotOptions::uncompressed()),
        ("chunked+v2", SnapshotOptions::default()),
    ] {
        let path = dir.join(format!("fig8_cmp_{}_{label}.h5", std::process::id()));
        let mut f = H5File::create(&path, 4096).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 16).unwrap();
        let rep = iokernel::write_snapshot_with(
            &mut f,
            &io,
            &sim.nbs.tree,
            &sim.part,
            &sim.grids,
            0.0,
            &opts,
        )
        .unwrap();
        if rep.io.stored_bytes < rep.io.bytes {
            let c = rep.io.codec_chunks;
            measured_ratio = rep.io.stored_bytes as f64 / rep.io.bytes as f64;
            measured_codec = if c.rc + c.tans >= c.lz {
                if c.tans >= c.rc {
                    Codec::SHUFFLE_DELTA_LZ_TANS
                } else {
                    Codec::SHUFFLE_DELTA_LZ_RC
                }
            } else {
                Codec::SHUFFLE_DELTA_LZ
            };
        }
        let c = rep.io.codec_chunks;
        println!(
            "{:>12} {:>12} {:>12} {:>7.2}x {:>14} {:>10}/{}/{}/{}",
            label,
            fmt_bytes(rep.io.bytes),
            fmt_bytes(rep.io.stored_bytes),
            rep.io.bytes as f64 / rep.io.stored_bytes.max(1) as f64,
            fmt_gbps(rep.io.bytes as f64, rep.io.real_seconds),
            c.store,
            c.lz,
            c.rc,
            c.tans,
        );
        std::fs::remove_file(&path).ok();
    }
    // the measured ratio, priced at the paper's scale with the per-codec
    // compress_bw entry the dominant codec selects
    let m = Machine::juqueen();
    let w = paper_depth6_workload(8192);
    let raw = m.estimate_write(&w, &IoTuning::default());
    let comp = m.estimate_write_compressed(
        &w,
        &IoTuning::default(),
        (w.total_bytes as f64 * measured_ratio) as u64,
        measured_codec,
    );
    println!(
        "  JuQueen model @8192 ranks, measured ratio {:.2}x ({}): raw {:.2} GB/s → compressed {:.2} GB/s",
        1.0 / measured_ratio,
        measured_codec.name(),
        raw.bandwidth / 1e9,
        comp.bandwidth / 1e9
    );
    (measured_ratio, measured_codec)
}

/// Steering rewrites: write one snapshot, then rewrite all of its cell
/// data N times (the long-running interactive scenario). A v2 file leaks
/// every abandoned extent and grows ~N×; a v2.1 file recycles them through
/// the free-space manager and stays near the single-write size; `repack()`
/// then compacts either to the fragmentation-free minimum.
fn rewrite_amplification() {
    use mpfluid::h5lite::{ReusePolicy, FORMAT_V2, FORMAT_V21};
    use mpfluid::iokernel::rewrite_snapshot_cells;
    use mpfluid::{var, DGRID_CELLS};
    const N: u32 = 6;
    println!("\n== steering rewrites ×{N}: file-size amplification (this host) ==");
    println!(
        "{:>14} {:>12} {:>12} {:>8} {:>12}",
        "format", "single", "rewritten", "amplif", "repacked"
    );
    for (label, version) in [("v2 (leak)", FORMAT_V2), ("v2.1 (reuse)", FORMAT_V21)] {
        let mut sc = Scenario::channel(2);
        sc.ranks = 8;
        let mut sim = sc.build();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let path = std::env::temp_dir().join(format!(
            "fig8_amp_{}_{version}.h5",
            std::process::id()
        ));
        let mut f = H5File::create_versioned(&path, 4096, version).unwrap();
        f.set_reuse_policy(ReusePolicy::Immediate);
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 8).unwrap();
        iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
            .unwrap();
        let single = std::fs::metadata(&path).unwrap().len();
        for step in 0..N {
            for g in sim.grids.iter_mut() {
                let data = vec![step as f32; DGRID_CELLS];
                g.cur.set_interior(var::P, &data);
            }
            rewrite_snapshot_cells(
                &mut f,
                &io,
                &sim.nbs.tree,
                &sim.part,
                &sim.grids,
                0.0,
                &SnapshotOptions::default(),
            )
            .unwrap();
        }
        let grown = std::fs::metadata(&path).unwrap().len();
        f.repack().unwrap();
        let repacked = std::fs::metadata(&path).unwrap().len();
        println!(
            "{:>14} {:>12} {:>12} {:>7.2}x {:>12}",
            label,
            fmt_bytes(single),
            fmt_bytes(grown),
            grown as f64 / single as f64,
            fmt_bytes(repacked),
        );
        std::fs::remove_file(&path).ok();
    }
}

/// Direct vs paged storage backend on identical snapshot sequences: the
/// per-step **commit-return** latency (what the solver blocks on — the
/// paged image absorbs the stream + sync), the **end-to-end** bandwidth
/// including the final `wait_durable` drain, and the **overlap
/// efficiency** — the fraction of flusher busy time hidden behind
/// subsequent steps' pack/compress instead of exposed in the drain.
/// Acceptance: paged commit-return ≤ 0.25× direct at ≥ 0.9× end-to-end
/// bandwidth.
fn direct_vs_paged(depth: u32, steps: u32) {
    use mpfluid::h5lite::Backing;
    use std::time::Instant;
    println!(
        "\n== direct vs paged backend ({steps} snapshots, depth-{depth} domain, 8 ranks, this host) =="
    );
    println!(
        "{:>8} {:>16} {:>14} {:>12} {:>12} {:>9}",
        "backend", "commit-return", "end-to-end", "bandwidth", "flush busy", "overlap"
    );
    let mut rows: Vec<(f64, f64)> = Vec::new(); // (per-step commit-return s, end-to-end B/s)
    for backing in [Backing::Direct, Backing::Paged] {
        let mut sc = Scenario::channel(depth);
        sc.ranks = 8;
        let sim = sc.build();
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
        let path = std::env::temp_dir().join(format!(
            "fig8_backend_{}_{backing:?}.h5",
            std::process::id()
        ));
        let mut f = H5File::create_backed(&path, 4096, backing).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 8).unwrap();
        let opts = SnapshotOptions {
            backing,
            ..SnapshotOptions::default()
        };
        let t0 = Instant::now();
        let mut commit_return = 0.0f64;
        let mut bytes = 0u64;
        for step in 0..steps {
            let ts = Instant::now();
            let rep = iokernel::write_snapshot_with(
                &mut f,
                &io,
                &sim.nbs.tree,
                &sim.part,
                &sim.grids,
                step as f64,
                &opts,
            )
            .unwrap();
            commit_return += ts.elapsed().as_secs_f64();
            bytes += rep.io.bytes;
        }
        let t_drain = Instant::now();
        f.wait_durable().unwrap();
        let drain = t_drain.elapsed().as_secs_f64();
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let busy = f.flush_stats().busy_seconds;
        let overlap = if busy > 0.0 {
            (1.0 - drain / busy).clamp(0.0, 1.0)
        } else {
            0.0
        };
        drop(f);
        std::fs::remove_file(&path).ok();
        println!(
            "{:>8} {:>13.1} ms {:>11.1} ms {:>12} {:>9.1} ms {:>8.0}%",
            format!("{backing:?}").to_lowercase(),
            commit_return / steps as f64 * 1e3,
            wall * 1e3,
            fmt_gbps(bytes as f64, wall),
            busy * 1e3,
            overlap * 100.0
        );
        rows.push((commit_return / steps as f64, bytes as f64 / wall));
    }
    println!(
        "  paged vs direct: commit-return {:.2}x (target ≤ 0.25x), \
         end-to-end bandwidth {:.2}x (target ≥ 0.9x)",
        rows[1].0 / rows[0].0,
        rows[1].1 / rows[0].1
    );
}

/// `lz_ratio`/`lz_codec` are the stored/raw ratio and dominant codec of
/// the adaptive cell-data path, measured on real channel-flow snapshots by
/// [`real_compression_comparison`].
fn modelled_fig8a(lz_ratio: f64, lz_codec: Codec) {
    println!("\n== Fig 8a (model): JuQueen, 1024³, 337 GB/checkpoint ==");
    println!(
        "{:>10} {:>16} {:>16} {:>18}",
        "ranks", "mpfluid GB/s", "VPIC-IO GB/s", "mpfluid+codec GB/s"
    );
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [2048u64, 4096, 8192, 16384, 32768] {
        let w = paper_depth6_workload(ranks);
        let mp = m.estimate_write(&w, &t);
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        let lz = m.estimate_write_compressed(
            &w,
            &t,
            (w.total_bytes as f64 * lz_ratio) as u64,
            lz_codec,
        );
        println!(
            "{:>10} {:>16.2} {:>16.2} {:>18.2}",
            ranks,
            mp.bandwidth / 1e9,
            vp / 1e9,
            lz.bandwidth / 1e9
        );
    }
}

fn modelled_fig8b() {
    println!("\n== Fig 8b (model): JuQueen, 2048³, 2.7 TB/checkpoint ==");
    println!(
        "{:>10} {:>16} {:>16}",
        "ranks", "mpfluid GB/s", "VPIC-IO GB/s"
    );
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [8192u64, 16384, 32768] {
        let w = paper_depth7_workload(ranks);
        let mp = m.estimate_write(&w, &t);
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        println!(
            "{:>10} {:>16.2} {:>16.2}",
            ranks,
            mp.bandwidth / 1e9,
            vp / 1e9
        );
    }
}

fn modelled_supermuc() {
    println!("\n== §5.3 (model): SuperMUC, 1024³, 337 GB/checkpoint ==");
    println!("{:>10} {:>16} {:>12}", "ranks", "model GB/s", "paper GB/s");
    let m = Machine::supermuc();
    for (ranks, paper) in [(2048u64, 21.4), (4096, 14.92), (8192, 4.64)] {
        let e = m.estimate_write(&paper_depth6_workload(ranks), &IoTuning::default());
        println!("{:>10} {:>16.2} {:>12.2}", ranks, e.bandwidth / 1e9, paper);
    }
}

fn real_vpic_write() {
    println!("\n== real VPIC-IO dump vs mpfluid snapshot at equal bytes (this host) ==");
    let mut sc = Scenario::channel(2);
    sc.ranks = 16;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 16);
    let dir = std::env::temp_dir();
    // mpfluid
    let mp_path = dir.join("fig8_mp.h5");
    let mut f = H5File::create(&mp_path, 4096).unwrap();
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 16).unwrap();
    let rep =
        iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0).unwrap();
    // VPIC at the same byte volume
    let vp_path = dir.join("fig8_vp.h5");
    let mut vf = H5File::create(&vp_path, 4096).unwrap();
    let vrep = vpic::write_dump(&mut vf, &io, vpic::particles_for_bytes(rep.io.bytes), 1).unwrap();
    println!(
        "  mpfluid: {} in {:.1} ms → {}",
        fmt_bytes(rep.io.bytes),
        rep.io.real_seconds * 1e3,
        fmt_gbps(rep.io.bytes as f64, rep.io.real_seconds)
    );
    println!(
        "  VPIC-IO: {} in {:.1} ms → {}",
        fmt_bytes(vrep.io.bytes),
        vrep.io.real_seconds * 1e3,
        fmt_gbps(vrep.io.bytes as f64, vrep.io.real_seconds)
    );
    std::fs::remove_file(&mp_path).ok();
    std::fs::remove_file(&vp_path).ok();
}

fn main() {
    // --quick: the CI bench-bitrot leg — the codec table and the model
    // tables exercise the whole bench surface in seconds, no large writes
    let quick = std::env::args().any(|a| a == "--quick");
    if quick {
        codec_v2_table(2);
        tans_vs_rc_throughput(3, true);
        // depth-1 domain: a few MB per snapshot — small enough for CI,
        // big enough for the commit-return / drain split to show
        direct_vs_paged(1, 4);
        modelled_fig8a(0.63, Codec::SHUFFLE_DELTA_LZ_TANS);
        modelled_fig8b();
        modelled_supermuc();
        return;
    }
    real_write_sweep();
    codec_v2_table(5);
    tans_vs_rc_throughput(8, false);
    let (lz_ratio, lz_codec) = real_compression_comparison();
    direct_vs_paged(2, 6);
    rewrite_amplification();
    real_vpic_write();
    modelled_fig8a(lz_ratio, lz_codec);
    modelled_fig8b();
    modelled_supermuc();
}
