//! Bench: **§5.2 ablations** — the effect of each hardware-aware
//! optimisation, both *real* (on this host, where file locking and
//! collective buffering are actually implemented in the pario layer) and
//! *modelled* (at the paper's scale on JuQueen).
//!
//! Run: `cargo bench --bench ablations`

use mpfluid::cluster::{paper_depth6_workload, IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel;
use mpfluid::pario::ParallelIo;
use mpfluid::util::{bench::measure, fmt_gbps};

fn configs() -> [(&'static str, IoTuning); 4] {
    [
        ("tuned (cb on, locks off, aligned)", IoTuning::default()),
        (
            "file locking ON",
            IoTuning {
                file_locking: true,
                ..IoTuning::default()
            },
        ),
        (
            "collective buffering OFF",
            IoTuning {
                collective_buffering: false,
                ..IoTuning::default()
            },
        ),
        (
            "alignment OFF",
            IoTuning {
                alignment: false,
                ..IoTuning::default()
            },
        ),
    ]
}

fn main() {
    // ---- real ablation on this host -------------------------------------
    println!("== real snapshot writes, depth-2 domain, 64 logical ranks ==");
    println!(
        "{:<38} {:>10} {:>22} {:>12}",
        "configuration", "ops", "wall-clock", "bandwidth"
    );
    let mut sc = Scenario::channel(2);
    sc.ranks = 64;
    let sim = sc.build();
    let dir = std::env::temp_dir();
    for (name, tuning) in configs() {
        let alignment = if tuning.alignment { 4096 } else { 1 };
        let io = ParallelIo::new(Machine::local(), tuning, 64);
        let mut n = 0u32;
        let mut bytes = 0u64;
        let mut ops = 0u64;
        let sample = measure(5, || {
            let path = dir.join(format!("abl_{}_{n}.h5", name.len()));
            n += 1;
            let mut f = H5File::create(&path, alignment).unwrap();
            iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 64).unwrap();
            let rep =
                iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
                    .unwrap();
            bytes = rep.io.bytes;
            ops = rep.io.write_ops;
            std::fs::remove_file(&path).ok();
        });
        println!(
            "{:<38} {:>10} {:>22} {:>12}",
            name,
            ops,
            sample.fmt_ms(),
            fmt_gbps(bytes as f64, sample.min)
        );
    }

    // ---- snapshot-content ablation (paper §3.1 future work) ---------------
    println!("\n== snapshot content selection (real, depth-2 domain) ==");
    use mpfluid::iokernel::SnapshotOptions;
    for (name, opts) in [
        ("full checkpoint (7 datasets)", SnapshotOptions::default()),
        ("output-only (4 datasets)", SnapshotOptions::output_only()),
    ] {
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), 64);
        let path = dir.join(format!("abl_sel_{}.h5", opts.n_datasets()));
        let mut f = H5File::create(&path, 4096).unwrap();
        iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 64).unwrap();
        let rep = iokernel::write_snapshot_with(
            &mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0, &opts,
        )
        .unwrap();
        println!(
            "  {:<32} {:>12} in {:>6.1} ms",
            name,
            mpfluid::util::fmt_bytes(rep.io.bytes),
            rep.io.real_seconds * 1e3
        );
        std::fs::remove_file(&path).ok();
    }

    // ---- modelled ablation at paper scale --------------------------------
    println!("\n== modelled on JuQueen, depth-6 (337 GB), 8192 ranks ==");
    println!("{:<38} {:>12} {:>10}", "configuration", "GB/s", "vs tuned");
    let m = Machine::juqueen();
    let w = paper_depth6_workload(8192);
    let base = m.estimate_write(&w, &IoTuning::default()).bandwidth;
    for (name, tuning) in configs() {
        let e = m.estimate_write(&w, &tuning);
        println!(
            "{:<38} {:>12.2} {:>9.2}x",
            name,
            e.bandwidth / 1e9,
            e.bandwidth / base
        );
    }
    println!("\n(paper §5.2: disabling locking and enabling collective buffering are\n\
              indispensable; alignment gives comparably small improvements)");
}
