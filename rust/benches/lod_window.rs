//! Bench: **byte-budgeted window queries over the LOD pyramid** — bytes
//! read and latency per (ROI size × budget), against the full-resolution
//! baseline the pre-pyramid reader was stuck with — plus the read-session
//! table (ISSUE 5): the per-call free functions re-parse the topology and
//! `LodIndex` on every query, a `SnapshotReader` session pays that once
//! and serves repeats from its chunk cache.
//!
//! The paper's second headline claim is that the output file's structure
//! supports "very fast interactive visualisation"; the pyramid is what
//! makes that hold under a *byte* budget, and the session is what makes a
//! real front end's query *sequence* cheap.
//!
//! Run: `cargo bench --bench lod_window`

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, ROW_BYTES};
use mpfluid::metrics::names;
use mpfluid::pario::ParallelIo;
use mpfluid::tree::BBox;
use mpfluid::util::{bench::measure, fmt_bytes};
use mpfluid::window::SnapshotReader;

/// Cell-data bytes of one grid row.
const RB: u64 = ROW_BYTES;

fn main() {
    // depth-3 cavity: 585 grids, 512 leaves — 40 MiB of current-generation
    // cell data, enough for the budget trade-off to show
    let mut sc = Scenario::cavity(3);
    sc.ranks = 8;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
    let path = std::env::temp_dir().join(format!("lod_bench_{}.h5", std::process::id()));
    let mut f = H5File::create(&path, 4096).unwrap();
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 8).unwrap();
    let rep = iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
        .unwrap();
    let lod = rep.lod.expect("pyramid missing");
    println!(
        "snapshot: {} raw cell data, pyramid {} levels, {} stored \
         ({:.1} % of file), fold {:.1} ms on the aggregator threads",
        fmt_bytes(rep.io.bytes),
        lod.levels,
        fmt_bytes(lod.stored_bytes),
        lod.stored_bytes as f64 * 100.0 / std::fs::metadata(&path).unwrap().len() as f64,
        rep.io.lod_seconds * 1e3,
    );

    let rois = [
        ("full domain", BBox::unit()),
        (
            "octant",
            BBox {
                min: [0.0; 3],
                max: [0.5; 3],
            },
        ),
        (
            "1/64 corner",
            BBox {
                min: [0.0; 3],
                max: [0.25; 3],
            },
        ),
    ];
    let budgets = [
        ("unlimited", u64::MAX),
        ("64 grids", 64 * RB),
        ("8 grids", 8 * RB),
        ("1 grid", RB),
    ];
    let reader = SnapshotReader::open(&f, 0.0).unwrap();
    println!(
        "\n{:>12} {:>10} {:>6} {:>6} {:>12} {:>9} {:>10}",
        "ROI", "budget", "level", "grids", "bytes read", "vs full", "latency"
    );
    for (roi_label, roi) in &rois {
        // the pre-pyramid baseline: every intersecting leaf
        let full = reader.budgeted(roi, u64::MAX).unwrap();
        let full_bytes = full.bytes_read.max(1);
        for (b_label, budget) in &budgets {
            let mut last = None;
            let sample = measure(5, || {
                last = Some(reader.budgeted(roi, *budget).unwrap());
            });
            let w = last.unwrap();
            println!(
                "{:>12} {:>10} {:>6} {:>6} {:>12} {:>8.1}% {:>10}",
                roi_label,
                b_label,
                w.level,
                w.grids.len(),
                fmt_bytes(w.bytes_read),
                w.bytes_read as f64 * 100.0 / full_bytes as f64,
                sample.fmt_ms(),
            );
        }
    }

    // == per-call session vs. long-lived session (ISSUE 5 acceptance) ====
    // the same zoom sequence, issued (a) through a throwaway session per
    // query — the one-shot pattern that replaced the removed PR-5 shims,
    // paying a file re-open and a LodIndex rebuild every call — and (b)
    // through one session. The index-build counts come from the session
    // metrics; the per-call path necessarily pays one build per query.
    let zoom_seq: Vec<(&BBox, u64)> = rois
        .iter()
        .flat_map(|(_, roi)| budgets.iter().map(move |(_, b)| (roi, *b)))
        .collect();
    let reps = 5u32;
    let per_call = measure(reps, || {
        for &(roi, budget) in &zoom_seq {
            SnapshotReader::open(&f, 0.0)
                .unwrap()
                .budgeted(roi, budget)
                .unwrap();
        }
    });
    let session_reader = SnapshotReader::open(&f, 0.0).unwrap();
    let session = measure(reps, || {
        for &(roi, budget) in &zoom_seq {
            session_reader.budgeted(roi, budget).unwrap();
        }
    });
    let rs = session_reader.read_stats();
    let hit_rate = rs.cache_hits as f64 * 100.0
        / (rs.cache_hits + rs.cache_misses).max(1) as f64;
    let n_queries = session_reader.metrics.counter(names::READER_QUERIES);
    // measure() runs one warmup pass on top of `reps`, so both rows below
    // account len × (reps + 1) executions
    let runs = zoom_seq.len() as u32 * (reps + 1);
    println!(
        "\n== per-call free function vs. session ({} queries × {} reps + warmup) ==",
        zoom_seq.len(),
        reps
    );
    println!(
        "{:>12} {:>12} {:>14} {:>14} {:>10}",
        "path", "whole seq", "index builds", "bytes read", "cache hit"
    );
    println!(
        "{:>12} {:>12} {:>14} {:>14} {:>10}",
        "per-call",
        per_call.fmt_ms(),
        format!("{runs} (1/query)"),
        "(per call)",
        "cold",
    );
    println!(
        "{:>12} {:>12} {:>14} {:>14} {:>9.1}%",
        "session",
        session.fmt_ms(),
        session_reader
            .metrics
            .counter(names::READER_INDEX_BUILDS)
            .to_string(),
        fmt_bytes(rs.read_bytes),
        hit_rate,
    );
    println!(
        "  session amortisation: index parsed once for {n_queries} queries; \
         mean-time speedup ×{:.2}",
        per_call.mean / session.mean.max(1e-12),
    );

    // progressive refinement: coarse-to-fine streaming of the full domain
    println!("\n== progressive refinement, full domain, 128-grid total budget ==");
    let steps = reader.progressive(&BBox::unit(), 128 * RB).unwrap();
    let mut cum = 0u64;
    for s in &steps {
        cum += s.bytes_read;
        println!(
            "  level {:>2}: {:>4} grids, {:>10} read ({} cumulative)",
            s.level,
            s.grids.len(),
            fmt_bytes(s.bytes_read),
            fmt_bytes(cum),
        );
    }
    std::fs::remove_file(&path).ok();
}
