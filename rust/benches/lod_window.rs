//! Bench: **byte-budgeted window queries over the LOD pyramid** — bytes
//! read and latency per (ROI size × budget), against the full-resolution
//! baseline the pre-pyramid reader was stuck with.
//!
//! The paper's second headline claim is that the output file's structure
//! supports "very fast interactive visualisation"; the pyramid is what
//! makes that hold under a *byte* budget: a whole-domain overview reads
//! one grid row instead of every leaf, and the level selection trades
//! resolution for bytes automatically as the ROI shrinks.
//!
//! Run: `cargo bench --bench lod_window`

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, ROW_BYTES};
use mpfluid::pario::ParallelIo;
use mpfluid::tree::BBox;
use mpfluid::util::{bench::measure, fmt_bytes};
use mpfluid::window;
use mpfluid::config::Scenario;

/// Cell-data bytes of one grid row.
const RB: u64 = ROW_BYTES;

fn main() {
    // depth-3 cavity: 585 grids, 512 leaves — 40 MiB of current-generation
    // cell data, enough for the budget trade-off to show
    let mut sc = Scenario::cavity(3);
    sc.ranks = 8;
    let sim = sc.build();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), 8);
    let path = std::env::temp_dir().join(format!("lod_bench_{}.h5", std::process::id()));
    let mut f = H5File::create(&path, 4096).unwrap();
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, 8).unwrap();
    let rep = iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, 0.0)
        .unwrap();
    let lod = rep.lod.expect("pyramid missing");
    println!(
        "snapshot: {} raw cell data, pyramid {} levels, {} stored \
         ({:.1} % of file), fold {:.1} ms on the aggregator threads",
        fmt_bytes(rep.io.bytes),
        lod.levels,
        fmt_bytes(lod.stored_bytes),
        lod.stored_bytes as f64 * 100.0 / std::fs::metadata(&path).unwrap().len() as f64,
        rep.io.lod_seconds * 1e3,
    );

    let rois = [
        ("full domain", BBox::unit()),
        (
            "octant",
            BBox {
                min: [0.0; 3],
                max: [0.5; 3],
            },
        ),
        (
            "1/64 corner",
            BBox {
                min: [0.0; 3],
                max: [0.25; 3],
            },
        ),
    ];
    let budgets = [
        ("unlimited", u64::MAX),
        ("64 grids", 64 * RB),
        ("8 grids", 8 * RB),
        ("1 grid", RB),
    ];
    println!(
        "\n{:>12} {:>10} {:>6} {:>6} {:>12} {:>9} {:>10}",
        "ROI", "budget", "level", "grids", "bytes read", "vs full", "latency"
    );
    for (roi_label, roi) in &rois {
        // the pre-pyramid baseline: every intersecting leaf
        let full = window::offline_window_budgeted(&f, 0.0, roi, u64::MAX).unwrap();
        let full_bytes = full.bytes_read.max(1);
        for (b_label, budget) in &budgets {
            let mut last = None;
            let sample = measure(5, || {
                last = Some(window::offline_window_budgeted(&f, 0.0, roi, *budget).unwrap());
            });
            let w = last.unwrap();
            println!(
                "{:>12} {:>10} {:>6} {:>6} {:>12} {:>8.1}% {:>10}",
                roi_label,
                b_label,
                w.level,
                w.grids.len(),
                fmt_bytes(w.bytes_read),
                w.bytes_read as f64 * 100.0 / full_bytes as f64,
                sample.fmt_ms(),
            );
        }
    }

    // progressive refinement: coarse-to-fine streaming of the full domain
    println!("\n== progressive refinement, full domain, 128-grid total budget ==");
    let steps = window::offline_window_progressive(&f, 0.0, &BBox::unit(), 128 * RB).unwrap();
    let mut cum = 0u64;
    for s in &steps {
        cum += s.bytes_read;
        println!(
            "  level {:>2}: {:>4} grids, {:>10} read ({} cumulative)",
            s.level,
            s.grids.len(),
            fmt_bytes(s.bytes_read),
            fmt_bytes(cum),
        );
    }
    std::fs::remove_file(&path).ok();
}
