//! Bench: **Fig 2b + Fig 2c** — multigrid solver scaling.
//!
//! Fig 2b (strong speed-up): fixed depth-2 problem, real V-cycle timings.
//! Fig 2c (time-to-solution vs grids/process): real per-grid solve rate on
//! this host combined with the interconnect model at paper rank counts.
//!
//! Run: `cargo bench --bench fig2_solver`

use mpfluid::cluster::Machine;
use mpfluid::config::Scenario;
use mpfluid::physics::RustBackend;
use mpfluid::solver::{self, SolverConfig};
use mpfluid::util::bench::measure;
use mpfluid::util::rng::Rng;
use mpfluid::var;

fn main() {
    // ---- Fig 2b: solver time on fixed problem, backend comparison -------
    println!("== Fig 2b: V-cycle cost on a fixed depth-2 domain (585 grids) ==");
    let sc = Scenario::cavity(2);
    let mut sim = sc.build();
    sim.step(&RustBackend); // realistic state
    let mut rng = Rng::new(3);
    for g in sim.grids.iter_mut() {
        let mut f = vec![0.0f32; mpfluid::DGRID_CELLS];
        rng.fill_f32(&mut f, -1.0, 1.0);
        g.temp.set_interior(var::P, &f);
    }
    let cfg = SolverConfig {
        max_cycles: 2,
        rtol: 0.0,
        ..SolverConfig::default()
    };
    let mut grids = sim.grids.clone();
    let mut sweeps = 0usize;
    let rust_sample = measure(5, || {
        grids.clone_from(&sim.grids);
        let stats = solver::solve_pressure(
            &sim.nbs,
            &mut grids,
            &sim.bc,
            &sim.params,
            &RustBackend,
            &cfg,
        );
        sweeps = stats.sweeps;
    });
    println!("  rust backend : {}  ({sweeps} sweeps)", rust_sample.fmt_ms());
    if let Ok(pjrt) = mpfluid::runtime::PjrtBackend::load_default() {
        let pjrt_sample = measure(3, || {
            grids.clone_from(&sim.grids);
            solver::solve_pressure(&sim.nbs, &mut grids, &sim.bc, &sim.params, &pjrt, &cfg);
        });
        println!(
            "  pjrt backend : {}  ({} dispatches)",
            pjrt_sample.fmt_ms(),
            pjrt.dispatch_count()
        );
    } else {
        println!("  pjrt backend : skipped (run `make artifacts`)");
    }

    // residual-reduction-per-second: V-cycle vs plain smoothing (the
    // multigrid claim behind Fig 2b's good strong scaling)
    println!("\n== multigrid vs plain smoothing at equal work ==");
    let mut g_mg = sim.grids.clone();
    let stats_mg = solver::solve_pressure(
        &sim.nbs,
        &mut g_mg,
        &sim.bc,
        &sim.params,
        &RustBackend,
        &SolverConfig {
            max_cycles: 3,
            rtol: 0.0,
            ..SolverConfig::default()
        },
    );
    println!(
        "  3 V-cycles:   residual {:.3e} → {:.3e}  ({} sweeps, {:.3} s)",
        stats_mg.initial_residual,
        stats_mg.final_residual,
        stats_mg.sweeps,
        stats_mg.seconds
    );

    // ---- Fig 2c: time-to-solution vs grids per process -------------------
    println!("\n== Fig 2c: time-to-solution vs grids/process (depth-6 domain, model) ==");
    let per_grid_step = {
        let sc1 = Scenario::cavity(1);
        let mut s1 = sc1.build();
        let sample = measure(3, || {
            s1.step(&RustBackend);
        });
        sample.min / s1.nbs.tree.len() as f64
    };
    let m = Machine::juqueen();
    println!(
        "{:>16} {:>10} {:>12} {:>12} {:>12}",
        "grids/process", "ranks", "compute", "exchange", "total"
    );
    let total_grids = 299_593u64;
    for ranks in [2048u64, 8192, 32768, 131_072] {
        let gpp = total_grids / ranks;
        let compute = per_grid_step * gpp as f64;
        let exch = m.estimate_exchange(ranks, total_grids * 16 * 16 * 5 * 4, total_grids * 6);
        println!(
            "{:>16} {:>10} {:>10.4} s {:>10.4} s {:>10.4} s",
            gpp,
            ranks,
            compute,
            exch,
            compute + exch
        );
    }
    println!("(shape: linear in grids/process until the exchange floor dominates)");
}
