//! Bench: **Fig 2a** — ghost-layer exchange cost.
//!
//! Measures the real three-phase exchange (bottom-up, horizontal, top-down,
//! all 5 variables) on this host across domain depths and rank counts, then
//! prices the measured traffic pattern on the JuQueen interconnect model at
//! the paper's scales.
//!
//! Run: `cargo bench --bench fig2_exchange`

use mpfluid::cluster::Machine;
use mpfluid::exchange::{self, ExchangeStats, Gen};
use mpfluid::nbs::NeighbourhoodServer;
use mpfluid::physics::bc::DomainBc;
use mpfluid::tree::dgrid::DGrid;
use mpfluid::tree::{sfc, BBox, SpaceTree};
use mpfluid::util::{bench::measure, fmt_bytes};
use mpfluid::var;

fn main() {
    println!("== real full exchange on this host ==");
    println!(
        "{:>7} {:>8} {:>8} {:>14} {:>10} {:>22}",
        "depth", "ranks", "grids", "cross-bytes", "msgs", "wall-clock"
    );
    let vars = [var::U, var::V, var::W, var::P, var::T];
    let mut measured: Vec<(u32, u64, u64)> = Vec::new();
    for depth in [1u32, 2, 3] {
        for ranks in [4u32, 16, 64] {
            let mut tree = SpaceTree::full(BBox::unit(), depth);
            sfc::partition(&mut tree, ranks);
            let nbs = NeighbourhoodServer::new(tree);
            let mut grids: Vec<DGrid> =
                nbs.tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
            let mut stats = ExchangeStats::default();
            let sample = measure(if depth == 3 { 3 } else { 10 }, || {
                stats = exchange::full_exchange(
                    &nbs,
                    &mut grids,
                    Gen::Cur,
                    &vars,
                    &DomainBc::all_walls(),
                );
            });
            println!(
                "{:>7} {:>8} {:>8} {:>14} {:>10} {:>22}",
                depth,
                ranks,
                nbs.tree.len(),
                fmt_bytes(stats.cross_rank_bytes),
                stats.messages,
                sample.fmt_ms()
            );
            if ranks == 64 {
                measured.push((depth, stats.cross_rank_bytes, stats.messages));
            }
        }
    }

    println!("\n== Fig 2a (model): traffic scaled to paper domains on JuQueen ==");
    println!("{:>8} {:>10} {:>14} {:>12}", "domain", "ranks", "cross-bytes", "time");
    let m = Machine::juqueen();
    let (d3, bytes3, msgs3) = measured.last().copied().unwrap();
    assert_eq!(d3, 3);
    for (name, depth, ranks) in [
        ("1024³", 6u32, 8192u64),
        ("2048³", 7, 32768),
        ("4096³", 8, 140_000),
    ] {
        let scale = 8u64.pow(depth - 3);
        let t = m.estimate_exchange(ranks, bytes3 * scale, msgs3 * scale);
        println!(
            "{:>8} {:>10} {:>14} {:>10.3} s",
            name,
            ranks,
            fmt_bytes(bytes3 * scale),
            t
        );
    }
    println!("(paper: full update of the 4096³ domain ≈ 0.1 s on 140k cores)");
}
