//! Minimal in-tree stand-in for the `anyhow` crate (the build environment
//! has no registry access, so the one external dependency is vendored as
//! the API subset this codebase uses):
//!
//! * [`Error`] — an opaque error carrying a context chain, built from any
//!   `std::error::Error` (walking its `source()` chain) or a message;
//! * [`Result<T>`] with the `Error` default;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, usable both on std-error results and on `anyhow::Result`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! `{e}` prints the outermost message, `{e:#}` the full chain joined with
//! `": "`, `{e:?}` an `anyhow`-style report with a "Caused by:" list.

use std::fmt;

/// An opaque error: a context chain, outermost message first, root cause
/// last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    /// Sealed conversion into [`super::Error`], implemented for std errors
    /// (blanket) and for `Error` itself — the coherence split that lets
    /// [`super::Context`] work on both std-error results and
    /// `anyhow::Result`.
    pub trait IntoError: Sized {
        fn into_error(self) -> super::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn from_std_error_via_question_mark() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e = io_fail().context("opening snapshot").unwrap_err();
        assert_eq!(format!("{e}"), "opening snapshot");
        assert_eq!(format!("{e:#}"), "opening snapshot: gone");
        assert_eq!(e.root_cause(), "gone");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.with_context(|| format!("outer {}", 8)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 8: inner 7");
        let n: Option<u32> = None;
        assert!(n.context("missing").is_err());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(12).is_err());
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
    }

    #[test]
    fn debug_report_lists_causes() {
        let e = io_fail().context("ctx").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx"));
        assert!(dbg.contains("Caused by:"));
    }
}
