//! **End-to-end driver** (DESIGN.md E13): the full three-layer stack on a
//! real workload, proving every layer composes —
//!
//! * L1/L2: Pallas stencil kernels inside the JAX step, AOT-compiled and
//!   executed through PJRT on every time step;
//! * L3: space-tree domain, neighbourhood server, three-phase ghost
//!   exchange, multigrid pressure solver, and the shared-file parallel I/O
//!   kernel with collective buffering writing periodic checkpoints;
//! * plus restart and offline-sliding-window read-back of the file.
//!
//! Reports the paper's headline metric — sustained checkpoint write
//! bandwidth (real on this host, modelled on JuQueen) — and the physics
//! log (divergence, kinetic energy, solver residuals). Results are
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_driver -- [--steps N] [--depth D]
//! ```

use std::time::Instant;

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::coordinator::Simulation;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::{ComputeBackend, RustBackend};
use mpfluid::runtime::PjrtBackend;
use mpfluid::steering::TrsSession;
use mpfluid::tree::BBox;
use mpfluid::util::{fmt_bytes, fmt_gbps};
use mpfluid::window;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str, default: u64| -> u64 {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let steps = get("--steps", 200);
    let depth = get("--depth", 2) as u32;
    let checkpoint_every = get("--checkpoint-every", 50);

    // --- build ------------------------------------------------------------
    let mut sc = Scenario::channel(depth);
    sc.ranks = 8;
    let mut sim = sc.build();
    let (backend, backend_name): (Box<dyn ComputeBackend>, &str) =
        match PjrtBackend::load_default() {
            Ok(b) => (Box::new(b), "pjrt (AOT Pallas/JAX artifacts)"),
            Err(e) => {
                eprintln!("WARNING: pjrt unavailable ({e}); using rust oracle");
                (Box::new(RustBackend), "rust oracle")
            }
        };
    println!("=== mpfluid end-to-end driver ===");
    println!("scenario: channel + cylinder, depth {depth}");
    println!(
        "domain:   {} grids ({} leaves, {} cells), {} logical ranks",
        sim.nbs.tree.len(),
        sim.nbs.tree.n_leaves(),
        sim.n_cells(),
        sc.ranks
    );
    println!("backend:  {backend_name}");

    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    let io_juqueen = ParallelIo::new(Machine::juqueen(), IoTuning::default(), 2048);
    let path = std::env::temp_dir().join("mpfluid_e2e.h5");
    let mut trs = TrsSession::create(&path, &sim, sc.alignment)?;

    // --- run with periodic checkpoints -------------------------------------
    let mut ckpt_real = Vec::new();
    let mut ckpt_modelled = Vec::new();
    let mut compute_s = 0.0f64;
    let t_run = Instant::now();
    for s in 0..steps {
        let rep = sim.step(backend.as_ref());
        compute_s += rep.seconds;
        if s % 25 == 0 || s + 1 == steps {
            println!(
                "step {:>4}  t={:.3}  div_rms={:.2e}  mg[{} cyc, r={:.1e}, {:.0} ms]  KE={:.4e}",
                rep.step,
                rep.t,
                rep.div_rms,
                rep.solve.cycles,
                rep.solve.final_residual,
                rep.solve.seconds * 1e3,
                sim.kinetic_energy()
            );
        }
        if (s + 1) % checkpoint_every == 0 {
            let srep = iokernel::write_snapshot(
                &mut trs.file,
                &io,
                &sim.nbs.tree,
                &sim.part,
                &sim.grids,
                sim.t,
            )?;
            // same snapshot priced on the paper's machine at 2048 ranks
            let jq = io_juqueen.machine.estimate_write(
                &mpfluid::cluster::WriteWorkload {
                    ranks: 2048,
                    total_bytes: srep.io.bytes,
                    n_datasets: 7,
                    n_grids: srep.n_grids,
                },
                &io_juqueen.tuning,
            );
            println!(
                "  checkpoint t={:.3}: {} in {:.1} ms → real {}  (pack {:.1} ms, {} write ops)",
                sim.t,
                fmt_bytes(srep.io.bytes),
                srep.io.real_seconds * 1e3,
                fmt_gbps(srep.io.bytes as f64, srep.io.real_seconds),
                srep.pack_seconds * 1e3,
                srep.io.write_ops,
            );
            ckpt_real.push((srep.io.bytes, srep.io.real_seconds));
            ckpt_modelled.push(jq.bandwidth);
        }
    }
    let wall = t_run.elapsed().as_secs_f64();

    // --- headline metrics ---------------------------------------------------
    let total_ckpt_bytes: u64 = ckpt_real.iter().map(|(b, _)| *b).sum();
    let total_ckpt_s: f64 = ckpt_real.iter().map(|(_, s)| *s).sum();
    println!("\n=== headline: checkpoint write bandwidth ===");
    println!(
        "  real (this host):    {} over {} checkpoints ({} total)",
        fmt_gbps(total_ckpt_bytes as f64, total_ckpt_s),
        ckpt_real.len(),
        fmt_bytes(total_ckpt_bytes)
    );
    println!(
        "  modelled (JuQueen, 2048 ranks, same layout): {:.2} GB/s",
        ckpt_modelled.iter().sum::<f64>() / ckpt_modelled.len().max(1) as f64 / 1e9
    );
    println!(
        "  I/O share of runtime: {:.1} % (compute {compute_s:.1} s / wall {wall:.1} s)",
        100.0 * total_ckpt_s / wall
    );

    // --- restart proof -------------------------------------------------------
    let file = H5File::open(&path)?;
    let times = iokernel::list_timesteps(&file);
    let snap = iokernel::read_snapshot(&file, *times.last().unwrap())?;
    let mut resumed = Simulation::from_snapshot(snap, sc.bc);
    let ke_before = resumed.kinetic_energy();
    resumed.step(backend.as_ref());
    println!("\n=== restart from t={:.3}: OK (KE {ke_before:.4e} → {:.4e}) ===",
        times.last().unwrap(), resumed.kinetic_energy());

    // --- offline sliding window ----------------------------------------------
    let zoom = BBox {
        min: [0.3, 0.3, 0.4],
        max: [0.7, 0.7, 0.6],
    };
    let w = window::SnapshotReader::open(&file, *times.last().unwrap())?.window(&zoom, 32)?;
    let payload: usize = w.iter().map(|g| g.data.len() * 4).sum();
    println!(
        "=== offline window over the wake: {} grids, {} (of {} file) ===",
        w.len(),
        fmt_bytes(payload as u64),
        fmt_bytes(file.data_bytes())
    );
    println!("\nall layers composed: L1/L2 kernels via PJRT, L3 tree+solver+I/O ✓");
    std::fs::remove_file(&path).ok();
    Ok(())
}
