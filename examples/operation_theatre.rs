//! **Fig 7 reproduction** — thermally coupled airflow in an operation
//! theatre, steered through TRS (paper §4).
//!
//! Setup: air inflow over one full wall, slightly open door opposite as
//! outlet, heated lamps (324.66 K), patient + assistants (299.50 K), other
//! surfaces cold — the Boussinesq-coupled scenario the paper uses to show
//! TRS's practical value: the first part of the simulation is the expensive
//! transient; re-evaluating a design change (lamps + 50 K) via rollback
//! costs only the remaining fraction ("≈ 33 % of time investment").
//!
//! ```bash
//! cargo run --release --example operation_theatre -- [--steps N]
//! ```

use std::time::Instant;

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::coordinator::Simulation;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::{ComputeBackend, RustBackend};
use mpfluid::runtime::PjrtBackend;
use mpfluid::steering::{self, SteerCommand, TrsSession};
use mpfluid::var;

/// Mean upward air velocity in a shell above the patient — the paper's
/// quality criterion is "airflow streaming away from the patient".
fn patient_updraft(sim: &Simulation) -> f64 {
    let region_min = [0.38, 0.38, 0.42];
    let region_max = [0.62, 0.62, 0.62];
    let mut sum = 0.0f64;
    let mut count = 0u64;
    let n = mpfluid::DGRID_N;
    for (i, node) in sim.nbs.tree.nodes.iter().enumerate() {
        if !node.is_leaf() {
            continue;
        }
        let b = &node.bbox;
        if b.max[0] < region_min[0] || b.min[0] > region_max[0] {
            continue;
        }
        let h = [
            b.extent(0) / n as f64,
            b.extent(1) / n as f64,
            b.extent(2) / n as f64,
        ];
        for ci in 0..n {
            for cj in 0..n {
                for ck in 0..n {
                    let p = [
                        b.min[0] + (ci as f64 + 0.5) * h[0],
                        b.min[1] + (cj as f64 + 0.5) * h[1],
                        b.min[2] + (ck as f64 + 0.5) * h[2],
                    ];
                    if (0..3).all(|a| p[a] >= region_min[a] && p[a] <= region_max[a])
                        && !sim.grids[i].cell_type(ci, cj, ck).is_solid()
                    {
                        let f = mpfluid::tree::dgrid::pidx(ci + 1, cj + 1, ck + 1);
                        sum += sim.grids[i].cur.var(var::W)[f] as f64;
                        count += 1;
                    }
                }
            }
        }
    }
    sum / count.max(1) as f64
}

fn mean_room_temp(sim: &Simulation) -> f64 {
    let mut sum = 0.0f64;
    let mut count = 0u64;
    let mut buf = vec![0.0f32; mpfluid::DGRID_CELLS];
    for (i, node) in sim.nbs.tree.nodes.iter().enumerate() {
        if node.is_leaf() {
            sim.grids[i].cur.extract_interior(var::T, &mut buf);
            sum += buf.iter().map(|&x| x as f64).sum::<f64>();
            count += buf.len() as u64;
        }
    }
    sum / count.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    let reload_frac = 0.4; // the paper reloads at t = 20 s of 50 s

    let sc = Scenario::theatre(1);
    let be: Box<dyn ComputeBackend> = match PjrtBackend::load_default() {
        Ok(b) => Box::new(b),
        Err(_) => Box::new(RustBackend),
    };
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    let path = std::env::temp_dir().join("mpfluid_theatre.h5");

    // ---- scenario 1: full run with standard lamps (324.66 K) ------------
    println!("=== scenario 1: lamps at 324.66 K, full horizon ===");
    let mut sim = sc.build();
    let mut trs = TrsSession::create(&path, &sim, sc.alignment)?;
    let reload_step = (steps as f64 * reload_frac) as u64;
    let t_full = Instant::now();
    for s in 0..steps {
        let rep = sim.step(be.as_ref());
        if s % 20 == 0 {
            println!(
                "  step {:>4} t={:.3}  T_room={:.2} K  updraft={:+.4}  div={:.1e}",
                rep.step,
                rep.t,
                mean_room_temp(&sim),
                patient_updraft(&sim),
                rep.div_rms
            );
        }
        if s + 1 == reload_step {
            trs.checkpoint(&sim, &io)?;
        }
    }
    trs.checkpoint(&sim, &io)?;
    let full_seconds = t_full.elapsed().as_secs_f64();
    let updraft_1 = patient_updraft(&sim);
    let temp_1 = mean_room_temp(&sim);

    // ---- scenario 2 via TRS: reload at 40 %, lamps + 50 K ---------------
    println!("\n=== scenario 2 via TRS: reload at {reload_frac:.0}0 %, lamps 374.66 K ===");
    let t_reload = trs.timesteps()[0];
    let mut steered = trs.rollback(t_reload, &io, sc.bc)?;
    steering::apply(&mut steered, &SteerCommand::SetHeatedSolidTemp { temp: 374.66 });
    let t_trs = Instant::now();
    for s in 0..(steps - reload_step) {
        let rep = steered.step(be.as_ref());
        if s % 20 == 0 {
            println!(
                "  step {:>4} t={:.3}  T_room={:.2} K  updraft={:+.4}",
                rep.step,
                rep.t,
                mean_room_temp(&steered),
                patient_updraft(&steered)
            );
        }
    }
    let trs_seconds = t_trs.elapsed().as_secs_f64();
    let updraft_2 = patient_updraft(&steered);
    let temp_2 = mean_room_temp(&steered);

    // ---- front-end read path: an epoch-pinned session on the branch -----
    // the visual-processing consumer reads the rollback snapshot through a
    // SnapshotReader session — it keeps serving this exact state even if
    // the steered run kept checkpointing into the same file
    let reader = trs.reader(t_reload)?;
    let patient_roi = mpfluid::tree::BBox {
        min: [0.38, 0.38, 0.42],
        max: [0.62, 0.62, 0.62],
    };
    let view = reader.window(&patient_roi, 16)?;
    let view_bytes: usize = view.iter().map(|g| g.data.len() * 4).sum();
    println!(
        "\n=== viewer session over the branch point (t={t_reload:.3}) ===\n  \
         patient region: {} grids, {} KiB payload, index parsed once",
        view.len(),
        view_bytes / 1024
    );

    // ---- Fig 7's comparison + §4's cost accounting -----------------------
    println!("\n=== results at the horizon ===");
    println!("  lamps 324.66 K: T_room={temp_1:.2} K  patient updraft={updraft_1:+.4}");
    println!("  lamps 374.66 K: T_room={temp_2:.2} K  patient updraft={updraft_2:+.4}");
    println!(
        "  hotter lamps raise the room temperature: ΔT = {:+.3} K",
        temp_2 - temp_1
    );
    println!("\n=== TRS cost accounting (paper: ≈33 % of a full rerun) ===");
    println!("  full run:        {full_seconds:.2} s ({steps} steps)");
    println!(
        "  TRS evaluation:  {trs_seconds:.2} s ({} steps) = {:.0} % of full",
        steps - reload_step,
        100.0 * trs_seconds / full_seconds
    );
    assert!(temp_2 > temp_1, "hotter lamps must heat the room");
    Ok(())
}
