//! **Sliding-window demo** (paper §2.3, §3.1, Fig 3): the same
//! level-of-detail-bounded exploration, online against a live run and
//! offline against the snapshot file — including the paper's headline
//! property that the returned data volume stays constant as the window
//! shrinks while the *resolution* increases.
//!
//! ```bash
//! cargo run --release --example sliding_window            # offline demo
//! cargo run --release --example sliding_window -- --online
//! ```

use std::sync::{Arc, RwLock};

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::RustBackend;
use mpfluid::steering::TrsSession;
use mpfluid::tree::BBox;
use mpfluid::window::{self, WindowGrid};

fn describe(label: &str, grids: &[WindowGrid]) {
    let bytes: usize = grids.iter().map(|g| g.data.len() * 4).sum();
    let depths: Vec<u32> = {
        let mut d: Vec<u32> = grids.iter().map(|g| g.depth).collect();
        d.sort_unstable();
        d.dedup();
        d
    };
    println!(
        "  {label:<28} {:>3} grids  depths {:?}  payload {} KiB",
        grids.len(),
        depths,
        bytes / 1024
    );
}

fn main() -> anyhow::Result<()> {
    let online = std::env::args().any(|a| a == "--online");
    let sc = Scenario::cavity(2); // depth 2: 73 grids, 64 leaves
    let mut sim = sc.build();
    for _ in 0..10 {
        sim.step(&RustBackend);
    }

    // windows of shrinking size, constant budget — the zoom sequence
    let windows = [
        ("full domain", BBox::unit()),
        (
            "half domain",
            BBox {
                min: [0.0; 3],
                max: [0.5, 1.0, 1.0],
            },
        ),
        (
            "octant",
            BBox {
                min: [0.25; 3],
                max: [0.75; 3],
            },
        ),
        (
            "small region at heater",
            BBox {
                min: [0.45, 0.45, 0.2],
                max: [0.55, 0.55, 0.3],
            },
        ),
    ];
    let budget: u32 = 16;

    if online {
        println!("=== online sliding window (Fig 3 query path) ===");
        let shared = Arc::new(RwLock::new(sim));
        let collector = window::Collector::spawn(shared.clone())?;
        println!("collector on {}", collector.addr);
        // one client session carries the whole zoom sequence over a single
        // connection — the collector runs one server-side session per
        // connection, so nothing renegotiates between frames
        let mut client = window::WindowClient::connect(collector.addr)?;
        for (label, bbox) in &windows {
            let grids = client.window(bbox, budget)?;
            describe(label, &grids);
        }
        // keep stepping while watching — live data over the same session
        shared.write().unwrap().step(&RustBackend);
        let after = client.window(&windows[0].1, budget)?;
        describe("full domain (next step)", &after);
    } else {
        println!("=== offline sliding window over the snapshot file ===");
        let path = std::env::temp_dir().join("mpfluid_window_demo.h5");
        let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
        let mut trs = TrsSession::create(&path, &sim, sc.alignment)?;
        trs.checkpoint(&sim, &io)?;
        let file = H5File::open(&path)?;
        let t = iokernel::list_timesteps(&file)[0];
        println!("snapshot t={t:.4}, file payload {} B", file.data_bytes());
        // one epoch-pinned read session serves the whole sequence: the
        // topology index parses once, repeats hit the session chunk cache
        let reader = window::SnapshotReader::open(&file, t)?;
        for (label, bbox) in &windows {
            let grids = reader.window(bbox, budget as usize)?;
            describe(label, &grids);
        }
        println!(
            "\nnote: payload stays bounded by the budget while the depth grows —\n\
             the \"zooming into the data\" of paper §2.3, now on offline data\n\
             (index parsed {}× for {} queries).",
            reader
                .metrics
                .counter(mpfluid::metrics::names::READER_INDEX_BUILDS),
            reader.metrics.counter(mpfluid::metrics::names::READER_QUERIES),
        );
        std::fs::remove_file(&path).ok();
    }
    Ok(())
}
