//! **Interactive zoom session over the LOD pyramid** (ISSUE 3 + 5): a
//! viewer opens one `SnapshotReader` session with a fixed per-frame byte
//! budget, paints a coarse whole-domain overview instantly, and zooms in —
//! each shrinking region of interest lands on a finer pyramid level
//! automatically, while the bytes read per frame stay bounded by the
//! budget, not by the domain. The session parses the topology + LOD index
//! once for the whole sequence and serves repeats from its chunk cache.
//!
//! ```bash
//! cargo run --release --example lod_zoom
//! ```

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel::{self, ROW_BYTES};
use mpfluid::pario::ParallelIo;
use mpfluid::physics::RustBackend;
use mpfluid::tree::BBox;
use mpfluid::util::fmt_bytes;
use mpfluid::window;

/// Cell-data bytes of one grid row.
const RB: u64 = ROW_BYTES;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::cavity(2); // depth 2: 73 grids, 64 leaves
    let mut sim = sc.build();
    for _ in 0..5 {
        sim.step(&RustBackend);
    }

    // write one snapshot; the pyramid folds on the aggregator threads
    // during the collective write
    let path = std::env::temp_dir().join("mpfluid_lod_zoom.h5");
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    let mut f = H5File::create(&path, 4096)?;
    iokernel::write_common(&mut f, &sim.params, &sim.nbs.tree, sc.ranks as u64)?;
    let rep = iokernel::write_snapshot(&mut f, &io, &sim.nbs.tree, &sim.part, &sim.grids, sim.t)?;
    let lod = rep.lod.expect("pyramid missing");
    println!(
        "snapshot t={:.4}: {} cell data, pyramid {} levels / {} stored, \
         fold {:.2} ms overlapped with the write",
        sim.t,
        fmt_bytes(rep.io.bytes),
        lod.levels,
        fmt_bytes(lod.stored_bytes),
        rep.io.lod_seconds * 1e3,
    );

    // --- the zoom session: fixed 4-grid budget per frame ----------------
    // one session for the whole exploration: the topology + pyramid index
    // parse once, the chunk cache carries across frames, and the epoch pin
    // keeps the view consistent even if a steering run rewrites underneath
    let reader = window::SnapshotReader::open(&f, sim.t)?;
    let budget = 4 * RB;
    println!(
        "\n=== zoom session (budget {} per frame) ===",
        fmt_bytes(budget)
    );
    let frames = [
        ("full domain", BBox::unit()),
        (
            "half domain",
            BBox {
                min: [0.0; 3],
                max: [0.5, 1.0, 1.0],
            },
        ),
        (
            "octant",
            BBox {
                min: [0.0; 3],
                max: [0.5; 3],
            },
        ),
        (
            "corner grid",
            BBox {
                min: [0.0; 3],
                max: [0.25; 3],
            },
        ),
    ];
    for (label, roi) in &frames {
        let w = reader.budgeted(roi, budget)?;
        let depths: Vec<u32> = {
            let mut d: Vec<u32> = w.grids.iter().map(|g| g.depth).collect();
            d.sort_unstable();
            d.dedup();
            d
        };
        println!(
            "  {label:<12} level {} ({}): {:>2} grids, depths {:?}, {} read",
            w.level,
            if w.from_pyramid { "pyramid" } else { "full res" },
            w.grids.len(),
            depths,
            fmt_bytes(w.bytes_read),
        );
    }

    // --- progressive refinement: first paint, then sharpen --------------
    println!("\n=== progressive refinement of the full domain ===");
    for step in reader.progressive(&BBox::unit(), 80 * RB)? {
        println!(
            "  level {}: {:>2} grids, {} read",
            step.level,
            step.grids.len(),
            fmt_bytes(step.bytes_read),
        );
    }

    // --- what the session amortised -------------------------------------
    let rs = reader.read_stats();
    println!(
        "\nsession: {} queries, index built {}× ({} index bytes), \
         {} payload served, {} physically read, cache {} hit / {} miss",
        reader.metrics.counter(mpfluid::metrics::names::READER_QUERIES),
        reader.metrics.counter(mpfluid::metrics::names::READER_INDEX_BUILDS),
        fmt_bytes(reader.metrics.counter(mpfluid::metrics::names::READER_INDEX_BYTES)),
        fmt_bytes(reader.metrics.counter(mpfluid::metrics::names::READER_PAYLOAD_BYTES)),
        fmt_bytes(rs.read_bytes),
        rs.cache_hits,
        rs.cache_misses,
    );
    assert_eq!(
        reader.metrics.counter(mpfluid::metrics::names::READER_INDEX_BUILDS),
        1,
        "a session must parse its index exactly once"
    );

    // the pyramid-bearing file stays structurally sound
    let vr = f.verify()?;
    assert!(vr.ok(), "verify found: {:?}", vr.errors);
    println!("\nverify: ok ({} datasets, {} chunks)", vr.n_datasets, vr.n_chunks);
    std::fs::remove_file(&path).ok();
    Ok(())
}
