//! Quickstart: the smallest complete tour of the public API.
//!
//! Builds a heated-cavity scenario, advances it a few dozen steps through
//! the compute backend (PJRT artifacts when present, pure-Rust oracle
//! otherwise), writes a checkpoint through the parallel I/O kernel, and
//! reads it back through the offline sliding window.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::config::Scenario;
use mpfluid::h5lite::H5File;
use mpfluid::iokernel;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::{ComputeBackend, RustBackend};
use mpfluid::runtime::PjrtBackend;
use mpfluid::steering::TrsSession;
use mpfluid::tree::BBox;
use mpfluid::window;

fn main() -> anyhow::Result<()> {
    // 1. a scenario: buoyancy-driven cavity with a heated sphere, depth 1
    //    (8 leaf d-grids of 16³ cells plus the root)
    let scenario = Scenario::cavity(1);
    let mut sim = scenario.build();
    println!(
        "domain: {} grids, {} cells, {} ranks",
        sim.nbs.tree.len(),
        sim.n_cells(),
        scenario.ranks
    );

    // 2. a compute backend: AOT artifacts through PJRT, or the oracle
    let backend: Box<dyn ComputeBackend> = match PjrtBackend::load_default() {
        Ok(b) => {
            println!("backend: pjrt ({} artifacts)", b.manifest.entries.len());
            Box::new(b)
        }
        Err(_) => {
            println!("backend: rust oracle (run `make artifacts` for pjrt)");
            Box::new(RustBackend)
        }
    };

    // 3. run — the coordinator drives predictor → divergence → multigrid
    //    pressure solve → projection each step
    for s in 0..30 {
        let rep = sim.step(backend.as_ref());
        if s % 10 == 0 {
            println!(
                "step {:>3}  t={:.3}  div_rms={:.2e}  V-cycles={}  KE={:.3e}",
                rep.step,
                rep.t,
                rep.div_rms,
                rep.solve.cycles,
                sim.kinetic_energy()
            );
        }
    }

    // 4. checkpoint through the shared-file I/O kernel
    let path = std::env::temp_dir().join("mpfluid_quickstart.h5");
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), scenario.ranks as u64);
    let mut trs = TrsSession::create(&path, &sim, scenario.alignment)?;
    trs.checkpoint(&sim, &io)?;
    println!("checkpoint written: {}", path.display());

    // 5. offline sliding window: zoom onto the heated sphere
    let file = H5File::open(&path)?;
    let t = iokernel::list_timesteps(&file)[0];
    let zoom = BBox {
        min: [0.35, 0.35, 0.1],
        max: [0.65, 0.65, 0.4],
    };
    let reader = window::SnapshotReader::open(&file, t)?;
    let grids = reader.window(&zoom, 8)?;
    println!("window over the heater: {} grids", grids.len());
    for g in &grids {
        let ts = &g.data[4 * mpfluid::DGRID_CELLS..5 * mpfluid::DGRID_CELLS];
        let tmax = ts.iter().cloned().fold(f32::MIN, f32::max);
        println!("  depth {}  T_max = {tmax:.2} K", g.depth);
    }
    std::fs::remove_file(&path).ok();
    Ok(())
}
