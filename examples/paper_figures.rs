//! **Paper-figure regeneration driver** — prints the series behind every
//! table and figure in the paper's evaluation (see DESIGN.md §4 for the
//! experiment index). Real byte movements and exchange patterns come from
//! miniature domains executed for real; the machine-scale timings come from
//! the calibrated cluster model (DESIGN.md §3 substitutions).
//!
//! ```bash
//! cargo run --release --example paper_figures            # everything
//! cargo run --release --example paper_figures -- fig8a   # one figure
//! ```

use mpfluid::cluster::{
    paper_depth6_workload, paper_depth7_workload, IoTuning, Machine, WriteWorkload,
};
use mpfluid::config::Scenario;
use mpfluid::exchange::{self, Gen};
use mpfluid::nbs::NeighbourhoodServer;
use mpfluid::physics::bc::DomainBc;
use mpfluid::physics::RustBackend;
use mpfluid::solver::{self, SolverConfig};
use mpfluid::tree::dgrid::DGrid;
use mpfluid::tree::{sfc, BBox, SpaceTree};
use mpfluid::util::rng::Rng;
use mpfluid::var;
use mpfluid::vpic;

fn main() {
    let which: Vec<String> = std::env::args().skip(1).collect();
    let want = |name: &str| which.is_empty() || which.iter().any(|w| w == name);
    if want("fig2a") {
        fig2a();
    }
    if want("fig2b") {
        fig2b();
    }
    if want("fig2c") {
        fig2c();
    }
    if want("fig8a") {
        fig8a();
    }
    if want("fig8b") {
        fig8b();
    }
    if want("supermuc") {
        supermuc();
    }
    if want("ablations") {
        ablations();
    }
    if want("vtk") {
        vtk_comparison();
    }
}

/// Measure one real full exchange on a depth-`d` tree with `ranks` logical
/// ranks; returns (cross-rank bytes, messages) per exchange.
fn measure_exchange(depth: u32, ranks: u32) -> (u64, u64) {
    let mut tree = SpaceTree::full(BBox::unit(), depth);
    sfc::partition(&mut tree, ranks);
    let nbs = NeighbourhoodServer::new(tree);
    let mut grids: Vec<DGrid> = nbs.tree.nodes.iter().map(|n| DGrid::new(n.uid())).collect();
    let vars = [var::U, var::V, var::W, var::P, var::T];
    let stats = exchange::full_exchange(
        &nbs,
        &mut grids,
        Gen::Cur,
        &vars,
        &DomainBc::all_walls(),
    );
    (stats.cross_rank_bytes, stats.messages)
}

/// Fig 2a — total ghost-layer exchange times for different process counts.
/// Real traffic is measured on depth 2–3 domains and scaled per-rank to the
/// paper's domain sizes; times come from the JuQueen interconnect model.
fn fig2a() {
    println!("\n=== Fig 2a: ghost-layer exchange time vs #processes (JuQueen model) ===");
    println!("{:>10} {:>14} {:>14} {:>12}", "ranks", "cross-bytes", "messages", "time");
    let m = Machine::juqueen();
    // measure the real communication pattern at miniature scale
    let (bytes3, msgs3) = measure_exchange(3, 64);
    // scale to the paper's depth-8 domain (4096³): grids grow 8× per depth
    let scale = 8u64.pow(8 - 3);
    for ranks in [1024u64, 4096, 16384, 65536, 140_000] {
        // per-rank traffic shrinks as ranks grow (strong scaling)
        let bytes = bytes3 * scale;
        let msgs = msgs3 * scale;
        let t = m.estimate_exchange(ranks, bytes, msgs);
        println!(
            "{:>10} {:>14} {:>14} {:>10.3} s",
            ranks,
            mpfluid::util::fmt_bytes(bytes),
            msgs,
            t
        );
    }
    println!("(paper: ~0.1 s for the full update on 140k cores)");
}

/// Fig 2b — strong speed-up of the multigrid-like solver. Real solves at
/// depth 2 with the thread pool capped (1..n cores) as the scaling proxy,
/// plus the communication-model overhead per rank count.
fn fig2b() {
    println!("\n=== Fig 2b: multigrid solver strong speed-up (real, this host) ===");
    let sc = Scenario::cavity(2);
    let mut sim = sc.build();
    // one warm-up step to get a realistic rhs
    sim.step(&RustBackend);
    let mut rng = Rng::new(7);
    for g in sim.grids.iter_mut() {
        let mut f = vec![0.0f32; mpfluid::DGRID_CELLS];
        rng.fill_f32(&mut f, -1.0, 1.0);
        g.temp.set_interior(var::P, &f);
    }
    let cfg = SolverConfig {
        max_cycles: 3,
        rtol: 0.0,
        ..SolverConfig::default()
    };
    println!("{:>8} {:>12} {:>10}", "threads", "solve time", "speedup");
    let mut t1 = 0.0;
    for threads in [1usize, 2, 4, 8] {
        let mut grids = sim.grids.clone();
        let stats = with_threads(threads, || {
            solver::solve_pressure(
                &sim.nbs,
                &mut grids,
                &sim.bc,
                &sim.params,
                &RustBackend,
                &cfg,
            )
        });
        if threads == 1 {
            t1 = stats.seconds;
        }
        println!(
            "{:>8} {:>10.3} s {:>9.2}x",
            threads,
            stats.seconds,
            t1 / stats.seconds
        );
    }
}

/// Run `f` with the crate's thread pool capped to `threads` workers.
fn with_threads<T>(threads: usize, f: impl FnOnce() -> T) -> T {
    mpfluid::util::set_max_threads(threads);
    let out = f();
    mpfluid::util::set_max_threads(0);
    out
}

/// Fig 2c — time-to-solution against grids per process.
fn fig2c() {
    println!("\n=== Fig 2c: time-to-solution vs grids/process (model + real kernel rate) ===");
    // real per-grid smoothing cost on this host:
    let sc = Scenario::cavity(1);
    let mut sim = sc.build();
    let t0 = std::time::Instant::now();
    let rep = sim.step(&RustBackend);
    let per_grid = t0.elapsed().as_secs_f64() / sim.nbs.tree.len() as f64;
    let _ = rep;
    let m = Machine::juqueen();
    println!(
        "{:>16} {:>10} {:>14} {:>12}",
        "grids/process", "ranks", "compute", "exchange"
    );
    let total_grids = 299_593u64; // depth 6
    for ranks in [2048u64, 8192, 32768, 131072] {
        let gpp = total_grids / ranks;
        let compute = per_grid * gpp as f64;
        let exch = m.estimate_exchange(ranks, gpp * ranks * 16 * 16 * 5 * 4, gpp * ranks * 6);
        println!(
            "{:>16} {:>10} {:>12.4} s {:>10.4} s",
            gpp,
            ranks,
            compute,
            exch
        );
    }
    println!("(shape: time/step ∝ grids per process until communication dominates)");
}

fn print_bandwidth_row(ranks: u64, mp: f64, vp: f64) {
    println!(
        "{:>10} {:>14.2} {:>14.2}",
        ranks,
        mp / 1e9,
        vp / 1e9
    );
}

/// Fig 8a — JuQueen sustained write bandwidth, depth-6 domain (337 GB),
/// mpfluid kernel vs VPIC-IO at equal bytes.
fn fig8a() {
    println!("\n=== Fig 8a: JuQueen write bandwidth, 1024³ domain, 337 GB/checkpoint ===");
    println!("{:>10} {:>14} {:>14}", "ranks", "mpfluid GB/s", "VPIC-IO GB/s");
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [2048u64, 4096, 8192, 16384, 32768] {
        let w = paper_depth6_workload(ranks);
        let mp = m.estimate_write(&w, &t).bandwidth;
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        print_bandwidth_row(ranks, mp, vp);
    }
    println!("(paper shape: flat 2048–8192, ~+20 % at 16384, drop at 32768)");
}

/// Fig 8b — the depth-7 domain (2.7 TB/checkpoint).
fn fig8b() {
    println!("\n=== Fig 8b: JuQueen write bandwidth, 2048³ domain, 2.7 TB/checkpoint ===");
    println!("{:>10} {:>14} {:>14}", "ranks", "mpfluid GB/s", "VPIC-IO GB/s");
    let m = Machine::juqueen();
    let t = IoTuning::default();
    for ranks in [8192u64, 16384, 32768] {
        let w = paper_depth7_workload(ranks);
        let mp = m.estimate_write(&w, &t).bandwidth;
        let vp = vpic::estimate(&m, ranks, w.total_bytes, &t);
        print_bandwidth_row(ranks, mp, vp);
    }
    println!("(paper: adequate scaling in the expected range — memory floor forbids <8192)");
}

/// §5.3 SuperMUC series — 21.4 / 14.92 / 4.64 GB/s at 2048 / 4096 / 8192.
fn supermuc() {
    println!("\n=== §5.3 SuperMUC: depth-6 domain, 337 GB/checkpoint ===");
    println!("{:>10} {:>14} {:>14}", "ranks", "model GB/s", "paper GB/s");
    let m = Machine::supermuc();
    let t = IoTuning::default();
    for (ranks, paper) in [(2048u64, 21.4), (4096, 14.92), (8192, 4.64)] {
        let w = paper_depth6_workload(ranks);
        let e = m.estimate_write(&w, &t);
        println!("{:>10} {:>14.2} {:>14.2}", ranks, e.bandwidth / 1e9, paper);
    }
}

/// §5.2 ablations — the contribution of each hardware-aware optimisation.
fn ablations() {
    println!("\n=== §5.2 ablations: JuQueen, depth-6, 8192 ranks ===");
    let m = Machine::juqueen();
    let w = paper_depth6_workload(8192);
    let configs: [(&str, IoTuning); 4] = [
        ("tuned (cb on, locks off, aligned)", IoTuning::default()),
        (
            "file locking ON",
            IoTuning {
                file_locking: true,
                ..IoTuning::default()
            },
        ),
        (
            "collective buffering OFF",
            IoTuning {
                collective_buffering: false,
                ..IoTuning::default()
            },
        ),
        (
            "alignment OFF",
            IoTuning {
                alignment: false,
                ..IoTuning::default()
            },
        ),
    ];
    println!("{:<38} {:>12} {:>10}", "configuration", "GB/s", "vs tuned");
    let base = m.estimate_write(&w, &configs[0].1).bandwidth;
    for (name, tuning) in &configs {
        let e = m.estimate_write(&w, tuning);
        println!(
            "{:<38} {:>12.2} {:>9.2}x",
            name,
            e.bandwidth / 1e9,
            e.bandwidth / base
        );
    }
    println!("(paper: locking & collective buffering indispensable; alignment small)");
}

/// §3 motivation — per-process VTK vs the shared-file kernel.
fn vtk_comparison() {
    println!("\n=== §3 motivation: one-file-per-process vs shared file (JuQueen, depth 6) ===");
    let m = Machine::juqueen();
    println!(
        "{:>10} {:>12} {:>14} {:>14}",
        "ranks", "files", "per-proc GB/s", "shared GB/s"
    );
    for ranks in [2048u64, 8192, 32768] {
        let w = paper_depth6_workload(ranks);
        let shared = m.estimate_write(&w, &IoTuning::default()).bandwidth;
        let indep = m
            .estimate_write(
                &w,
                &IoTuning {
                    collective_buffering: false,
                    file_locking: false,
                    alignment: false,
                },
            )
            .bandwidth;
        println!(
            "{:>10} {:>12} {:>14.2} {:>14.2}",
            ranks,
            ranks, // one file per process per step
            indep / 1e9,
            shared / 1e9
        );
    }
    let _ = WriteWorkload {
        ranks: 0,
        total_bytes: 0,
        n_datasets: 0,
        n_grids: 0,
    };
}
