//! **Fig 6 reproduction** — Time Reversible Steering on the Schäfer–Turek
//! channel (DFG benchmark [18] of the paper): flow past a cylinder at
//! Re ≈ 100.
//!
//! The experiment mirrors the paper's §4 narrative exactly:
//!
//! 1. simulate the base setup from t = 0 to t = T, checkpointing at T/2;
//! 2. *reverse in time*: roll back to T/2 on a branch file;
//! 3. branch A — shift the obstacle downstream and resume to T;
//! 4. branch B — keep the original obstacle and add a second one; resume;
//! 5. report the wake signature (cross-stream velocity probe) of all three
//!    trajectories — "not separate simulations, but branchings within the
//!    framework".
//!
//! ```bash
//! cargo run --release --example channel_flow_trs -- [--steps N] [--depth D]
//! ```

use mpfluid::config::Scenario;
use mpfluid::cluster::{IoTuning, Machine};
use mpfluid::coordinator::Simulation;
use mpfluid::pario::ParallelIo;
use mpfluid::physics::{ComputeBackend, RustBackend};
use mpfluid::runtime::PjrtBackend;
use mpfluid::steering::{self, SteerCommand, TrsSession};
use mpfluid::var;

fn backend() -> Box<dyn ComputeBackend> {
    match PjrtBackend::load_default() {
        Ok(b) => Box::new(b),
        Err(_) => Box::new(RustBackend),
    }
}

/// Probe the cross-stream velocity just behind the (original) obstacle —
/// the oscillation of this signal is the vortex-shedding signature.
fn probe_v(sim: &Simulation) -> f32 {
    let p = [0.45, 0.55, 0.5];
    for (i, n) in sim.nbs.tree.nodes.iter().enumerate() {
        if n.is_leaf() && n.bbox.contains_point(p) {
            let h = [
                n.bbox.extent(0) / mpfluid::DGRID_N as f64,
                n.bbox.extent(1) / mpfluid::DGRID_N as f64,
                n.bbox.extent(2) / mpfluid::DGRID_N as f64,
            ];
            let c: Vec<usize> = (0..3)
                .map(|a| (((p[a] - n.bbox.min[a]) / h[a]) as usize).min(mpfluid::DGRID_N - 1))
                .collect();
            let fidx = mpfluid::tree::dgrid::pidx(c[0] + 1, c[1] + 1, c[2] + 1);
            return sim.grids[i].cur.var(var::V)[fidx];
        }
    }
    0.0
}

fn run(sim: &mut Simulation, be: &dyn ComputeBackend, steps: u64, label: &str) -> Vec<f32> {
    let mut series = Vec::with_capacity(steps as usize);
    for s in 0..steps {
        let rep = sim.step(be);
        series.push(probe_v(sim));
        if s % 20 == 0 {
            println!(
                "  [{label}] step {:>4} t={:.3} div={:.1e} v_probe={:+.4}",
                rep.step,
                rep.t,
                rep.div_rms,
                series.last().unwrap()
            );
        }
    }
    series
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let get = |flag: &str, default: u64| -> u64 {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let steps = get("--steps", 120);
    let depth = get("--depth", 1) as u32;
    let half = steps / 2;

    let sc = Scenario::channel(depth);
    let be = backend();
    let io = ParallelIo::new(Machine::local(), IoTuning::default(), sc.ranks as u64);
    let path = std::env::temp_dir().join("mpfluid_fig6.h5");

    println!("=== base run (t = 0 … T), checkpoint at T/2 ===");
    let mut sim = sc.build();
    let mut trs = TrsSession::create(&path, &sim, sc.alignment)?;
    let mut base = run(&mut sim, be.as_ref(), half, "base");
    trs.checkpoint(&sim, &io)?;
    let t_mid = sim.t;
    base.extend(run(&mut sim, be.as_ref(), steps - half, "base"));
    trs.checkpoint(&sim, &io)?;

    println!("=== TRS rollback to t = {t_mid:.3}; branch A: obstacle shifted ===");
    let mut sim_a = trs.rollback(t_mid, &io, sc.bc)?;
    steering::apply(&mut sim_a, &SteerCommand::ClearObstacles);
    steering::apply(
        &mut sim_a,
        &SteerCommand::AddObstacle {
            centre: [0.45, 0.5, 0.5],
            radius: 0.125,
            temp: None,
            ignore_axis: Some(2),
        },
    );
    let branch_a = run(&mut sim_a, be.as_ref(), steps - half, "A:shifted");

    println!("=== TRS rollback again; branch B: second obstacle ===");
    let mut sim_b = trs.rollback(t_mid, &io, sc.bc)?;
    steering::apply(
        &mut sim_b,
        &SteerCommand::AddObstacle {
            centre: [0.55, 0.3, 0.5],
            radius: 0.08,
            temp: None,
            ignore_axis: Some(2),
        },
    );
    let branch_b = run(&mut sim_b, be.as_ref(), steps - half, "B:second");

    // --- wake signatures -------------------------------------------------
    let osc = |s: &[f32]| -> f32 {
        let mean = s.iter().sum::<f32>() / s.len() as f32;
        (s.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / s.len() as f32).sqrt()
    };
    let tail = &base[half as usize..];
    println!("\n=== wake signature (probe-v RMS oscillation over t > T/2) ===");
    println!("  base:              {:.5}", osc(tail));
    println!("  branch A shifted:  {:.5}", osc(&branch_a));
    println!("  branch B 2nd obst: {:.5}", osc(&branch_b));
    println!(
        "\nall three trajectories share history up to t = {t_mid:.3} and diverge after\n\
         (base file: {}, branches: *.branch*.h5 alongside it)",
        path.display()
    );
    assert!(osc(&branch_a) != osc(tail) || osc(&branch_b) != osc(tail));
    Ok(())
}
